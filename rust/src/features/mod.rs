//! Hashed-feature expansion (§4 of the paper): turn 0-bit CWS samples
//! into the sparse one-hot matrix a linear learner consumes.
//!
//! For `b_i` bits of `i*` and `k` samples, sample `j`'s code
//! `c_j = i*_j mod 2^{b_i}` becomes a 1 at column `j · 2^{b_i} + c_j`.
//! The result is a `2^{b_i} × k`-dimensional binary matrix with exactly
//! `k` ones per row, so `⟨φ(u), φ(v)⟩ / k` is precisely the b-bit
//! collision estimator of `K_MM(u, v)` — a linear kernel approximating
//! the min-max kernel, which is the whole point of the pipeline.

pub mod codes;

pub use codes::{CodeMatrix, PackedCodes};

use crate::cws::sampler::CwsSample;
use crate::cws::schemes::Scheme;
use crate::data::sparse::{Csr, CsrBuilder};

/// Total bit budget per sample: `2^{i_bits + t_bits}` columns per hash
/// slot must stay addressable (and sane) — beyond this the expansion
/// would allocate gigabytes per k.
pub const MAX_CODE_BITS: usize = 24;

/// Invalid [`Expansion`] configurations. Returned (not panicked) so
/// serving paths can reject bad requests gracefully.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpansionError {
    /// `i_bits` must be in `[1, 16]`.
    IBitsOutOfRange(u8),
    /// `i_bits + t_bits` exceeds [`MAX_CODE_BITS`] — the `u8` shift in
    /// [`Expansion::code_space`] would overflow / the one-hot dimension
    /// would explode.
    CodeSpaceTooLarge { i_bits: u8, t_bits: u8 },
    /// `k · 2^(i_bits + t_bits)` does not fit the `u32` column index
    /// space — columns would silently wrap and features collide.
    DimensionOverflow { k: usize, code_bits: u8 },
    /// `k` must be positive.
    ZeroSamples,
}

impl std::fmt::Display for ExpansionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExpansionError::IBitsOutOfRange(b) => {
                write!(f, "i_bits must be in [1, 16], got {b}")
            }
            ExpansionError::CodeSpaceTooLarge { i_bits, t_bits } => write!(
                f,
                "i_bits ({i_bits}) + t_bits ({t_bits}) exceeds {MAX_CODE_BITS} code bits"
            ),
            ExpansionError::DimensionOverflow { k, code_bits } => write!(
                f,
                "k ({k}) x 2^{code_bits} columns overflows the u32 feature-index space"
            ),
            ExpansionError::ZeroSamples => write!(f, "k must be positive"),
        }
    }
}

impl std::error::Error for ExpansionError {}

/// Configuration of the expansion: bits of `i*` and (rarely) of `t*`.
/// With `t_bits > 0` the code space per sample is `2^{b_i + b_t}`
/// (Figure 8's 2-bit-t* variant).
#[derive(Debug, Clone, Copy)]
pub struct Expansion {
    pub k: usize,
    pub i_bits: u8,
    pub t_bits: u8,
}

impl Expansion {
    /// Validating constructor — the serving-path entry point.
    pub fn checked(k: usize, i_bits: u8, t_bits: u8) -> Result<Self, ExpansionError> {
        if k == 0 {
            return Err(ExpansionError::ZeroSamples);
        }
        if !(1..=16).contains(&i_bits) {
            return Err(ExpansionError::IBitsOutOfRange(i_bits));
        }
        if i_bits as usize + t_bits as usize > MAX_CODE_BITS {
            return Err(ExpansionError::CodeSpaceTooLarge { i_bits, t_bits });
        }
        // Columns are u32 (`column()` casts); the full k·2^bits space
        // must fit or sample blocks silently alias after wrapping.
        let code_bits = i_bits + t_bits;
        match k.checked_mul(1usize << code_bits) {
            Some(dim) if dim <= u32::MAX as usize => {}
            _ => return Err(ExpansionError::DimensionOverflow { k, code_bits }),
        }
        Ok(Self { k, i_bits, t_bits })
    }

    /// Convenience constructor for static configurations; panics on an
    /// invalid `i_bits` (use [`Expansion::checked`] on request paths).
    pub fn new(k: usize, i_bits: u8) -> Self {
        Self::checked(k, i_bits, 0).expect("invalid Expansion configuration")
    }

    /// Add `t_bits` of `t*` to the per-sample code. Validates that the
    /// combined code space fits (previously this was an assert that
    /// could panic deep in a serving path).
    pub fn with_t_bits(self, t_bits: u8) -> Result<Self, ExpansionError> {
        Self::checked(self.k, self.i_bits, t_bits)
    }

    /// Codes per sample.
    pub fn code_space(&self) -> usize {
        1usize << (self.i_bits + self.t_bits)
    }

    /// Total output dimensionality `k · 2^{b_i + b_t}`.
    pub fn dim(&self) -> usize {
        self.k * self.code_space()
    }

    /// The scheme whose collision event this expansion's inner product
    /// counts (used by tests to cross-validate).
    pub fn scheme(&self) -> Scheme {
        Scheme { i_bits: Some(self.i_bits), t_bits: Some(self.t_bits) }
    }

    /// Column index for sample `j`.
    #[inline]
    pub fn column(&self, j: usize, s: &CwsSample) -> u32 {
        let i_part = (s.i_star as u64) & ((1u64 << self.i_bits) - 1);
        let code = if self.t_bits == 0 {
            i_part
        } else {
            let t_part = s.t_star.rem_euclid(1i64 << self.t_bits) as u64;
            (t_part << self.i_bits) | i_part
        };
        (j * self.code_space()) as u32 + code as u32
    }

    /// Expand one vector's samples into a sorted sparse row (indices,
    /// values) with exactly `k` ones.
    pub fn expand_row(&self, samples: &[CwsSample]) -> (Vec<u32>, Vec<f32>) {
        let (mut idx, mut vals) = (Vec::new(), Vec::new());
        self.expand_row_into(samples, &mut idx, &mut vals);
        (idx, vals)
    }

    /// [`Expansion::expand_row`] into caller-owned buffers, so batch
    /// expansion reuses one (indices, values) pair instead of
    /// allocating `vec![1.0; k]` per row.
    pub fn expand_row_into(&self, samples: &[CwsSample], idx: &mut Vec<u32>, vals: &mut Vec<f32>) {
        assert_eq!(samples.len(), self.k);
        idx.clear();
        idx.extend(samples.iter().enumerate().map(|(j, s)| self.column(j, s)));
        // One column per sample block ⇒ already strictly increasing.
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]));
        vals.clear();
        vals.resize(self.k, 1.0);
    }

    /// Expand a batch of per-row samples (rows with `None` — empty input
    /// vectors — become empty feature rows) into the legacy CSR
    /// representation. The learning layer's default is the leaner
    /// [`Expansion::encode`]; this stays as the compatibility/IO path.
    pub fn expand(&self, samples: &[Option<Vec<CwsSample>>]) -> Csr {
        let mut b = CsrBuilder::new(self.dim());
        let (mut idx, mut vals) = (Vec::with_capacity(self.k), Vec::with_capacity(self.k));
        for row in samples {
            match row {
                Some(s) => {
                    self.expand_row_into(s, &mut idx, &mut vals);
                    b.push_sorted_row(&idx, &vals);
                }
                None => b.push_sorted_row(&[], &[]),
            }
        }
        b.finish()
    }

    /// Encode a batch of per-row samples as a [`CodeMatrix`] — the
    /// one-hot columns alone, no CSR scaffolding or values array. This
    /// is what `Pipeline::fit`/`hash_dataset` train on;
    /// [`CodeMatrix::to_csr`] round-trips to exactly
    /// [`Expansion::expand`]'s output.
    pub fn encode(&self, samples: &[Option<Vec<CwsSample>>]) -> CodeMatrix {
        let mut codes = Vec::with_capacity(samples.len() * self.k);
        let mut empty = Vec::with_capacity(samples.len());
        for row in samples {
            match row {
                Some(s) => {
                    assert_eq!(s.len(), self.k);
                    codes.extend(s.iter().enumerate().map(|(j, smp)| self.column(j, smp)));
                    empty.push(false);
                }
                None => {
                    codes.resize(codes.len() + self.k, 0);
                    empty.push(true);
                }
            }
        }
        CodeMatrix::from_parts(self.k, self.dim(), codes, empty)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::CwsHasher;
    use crate::cws::schemes::collision_fraction;
    use crate::data::sparse::dot;

    fn samples_for(u: &[f32], k: usize, seed: u64) -> Vec<CwsSample> {
        CwsHasher::new(seed, k).hash_dense(u)
    }

    #[test]
    fn row_has_exactly_k_ones() {
        let u = [1.0f32, 0.5, 2.0, 0.0];
        let e = Expansion::new(64, 4);
        let (idx, vals) = e.expand_row(&samples_for(&u, 64, 1));
        assert_eq!(idx.len(), 64);
        assert!(vals.iter().all(|&v| v == 1.0));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        // Sample j's column lands in block j.
        for (j, &c) in idx.iter().enumerate() {
            assert!((c as usize) / e.code_space() == j);
        }
    }

    #[test]
    fn inner_product_equals_collision_count() {
        let u = [1.0f32, 3.0, 0.5, 2.0, 0.0, 1.0];
        let v = [2.0f32, 1.0, 0.5, 1.0, 1.0, 0.0];
        for i_bits in [1u8, 2, 4, 8] {
            let k = 512;
            let e = Expansion::new(k, i_bits);
            let su = samples_for(&u, k, 9);
            let sv = samples_for(&v, k, 9);
            let m = e.expand(&[Some(su.clone()), Some(sv.clone())]);
            let ip = dot(m.row(0), m.row(1));
            let coll = collision_fraction(e.scheme(), &su, &sv) * k as f64;
            assert!((ip - coll).abs() < 1e-9, "b_i={i_bits}: {ip} vs {coll}");
        }
    }

    #[test]
    fn t_bits_variant_matches_its_scheme() {
        let u = [1.0f32, 3.0, 0.5, 2.0];
        let v = [2.0f32, 1.0, 0.5, 1.0];
        let k = 512;
        let e = Expansion::new(k, 4).with_t_bits(2).unwrap();
        let su = samples_for(&u, k, 17);
        let sv = samples_for(&v, k, 17);
        let m = e.expand(&[Some(su.clone()), Some(sv.clone())]);
        let ip = dot(m.row(0), m.row(1));
        let coll = collision_fraction(e.scheme(), &su, &sv) * k as f64;
        assert!((ip - coll).abs() < 1e-9);
        assert_eq!(e.dim(), k * 64);
    }

    #[test]
    fn dims_and_bounds() {
        let e = Expansion::new(128, 8);
        assert_eq!(e.dim(), 128 * 256);
        let u = [0.1f32, 5.0, 0.2];
        let m = e.expand(&[Some(samples_for(&u, 128, 3))]);
        assert_eq!(m.cols(), e.dim());
        m.check_invariants().unwrap();
    }

    #[test]
    fn expand_row_into_reuses_dirty_buffers() {
        // The buffers may arrive with arbitrary contents and lengths;
        // every call must leave exactly the fresh-allocation result.
        let e = Expansion::new(16, 4);
        let s1 = samples_for(&[1.0, 2.0], 16, 1);
        let s2 = samples_for(&[0.5, 3.0, 0.1], 16, 1);
        let (mut idx, mut vals) = (vec![9u32; 3], vec![0.25f32; 40]);
        e.expand_row_into(&s1, &mut idx, &mut vals);
        assert_eq!((idx.clone(), vals.clone()), e.expand_row(&s1));
        e.expand_row_into(&s2, &mut idx, &mut vals);
        assert_eq!((idx, vals), e.expand_row(&s2));
    }

    #[test]
    fn empty_rows_expand_empty() {
        let e = Expansion::new(8, 2);
        let m = e.expand(&[None, Some(samples_for(&[1.0f32, 2.0], 8, 5))]);
        assert_eq!(m.row(0).nnz(), 0);
        assert_eq!(m.row(1).nnz(), 8);
    }

    #[test]
    #[should_panic(expected = "IBitsOutOfRange")]
    fn zero_i_bits_rejected() {
        Expansion::new(4, 0);
    }

    #[test]
    fn checked_rejects_bad_configs() {
        assert_eq!(Expansion::checked(0, 8, 0), Err(ExpansionError::ZeroSamples));
        assert_eq!(Expansion::checked(4, 0, 0), Err(ExpansionError::IBitsOutOfRange(0)));
        assert_eq!(Expansion::checked(4, 17, 0), Err(ExpansionError::IBitsOutOfRange(17)));
        assert!(Expansion::checked(4, 16, 8).is_ok());
        assert_eq!(
            Expansion::checked(4, 16, 9),
            Err(ExpansionError::CodeSpaceTooLarge { i_bits: 16, t_bits: 9 })
        );
    }

    #[test]
    fn checked_rejects_u32_column_overflow() {
        // 512 · 2^24 > u32::MAX: sample blocks would alias after the
        // `as u32` cast in `column()`.
        assert_eq!(
            Expansion::checked(512, 16, 8),
            Err(ExpansionError::DimensionOverflow { k: 512, code_bits: 24 })
        );
        // 255 · 2^24 < 2^32: fine.
        assert!(Expansion::checked(255, 16, 8).is_ok());
    }

    #[test]
    fn with_t_bits_no_longer_panics_on_overflow() {
        // The old API asserted; this must now be a recoverable error
        // even for t_bits values that would overflow the u8 shift.
        let e = Expansion::new(8, 16);
        assert!(e.with_t_bits(200).is_err());
        let ok = e.with_t_bits(4).unwrap();
        assert_eq!(ok.code_space(), 1 << 20);
        let err = Expansion::new(8, 12).with_t_bits(13).unwrap_err();
        assert!(err.to_string().contains("exceeds"));
    }
}
