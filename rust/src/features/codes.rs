//! Dense one-hot **code matrix** — the fast-path representation of
//! hashed features.
//!
//! The §4 expansion produces exactly one active column per `(row,
//! sample)` pair, so a CSR with `k` ones per row stores three arrays
//! (indptr, indices, values) to say what a dense `[n × k]` slab of
//! `u32` column codes says alone. [`CodeMatrix`] is that slab plus an
//! empty-row mask: ~3× less memory traffic than the CSR (no `f32`
//! values, no indptr), and the learning layer's inner products collapse
//! to `k` gathers with no multiplies (see `svm::rowset`).
//!
//! Built by [`crate::features::Expansion::encode`]; [`CodeMatrix::to_csr`]
//! is the compatibility/export path (LIBSVM IO, CSR-consuming code) and
//! reproduces `Expansion::expand` exactly.

use crate::data::sparse::{Csr, CsrBuilder};

/// `[n × k]` one-hot column codes, row-major, with an empty-row mask.
///
/// Row `i`'s `k` codes are absolute column indices into the
/// `k · 2^{b_i+b_t}`-dimensional one-hot space — sample `j`'s code
/// lives in block `j`, so each row's codes are strictly increasing.
/// Rows hashed from an all-zero input vector (no samples) are marked
/// empty and behave as all-zero feature rows everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct CodeMatrix {
    k: usize,
    /// One-hot dimensionality `k · 2^{b_i+b_t}` (the CSR `cols()`).
    dim: usize,
    /// Row-major `[n × k]` absolute column codes; empty rows hold zeros.
    codes: Vec<u32>,
    /// Per-row marker for empty input vectors.
    empty: Vec<bool>,
}

impl CodeMatrix {
    pub(crate) fn from_parts(k: usize, dim: usize, codes: Vec<u32>, empty: Vec<bool>) -> Self {
        debug_assert!(k > 0 && dim % k == 0);
        debug_assert_eq!(codes.len(), empty.len() * k);
        Self { k, dim, codes, empty }
    }

    pub fn rows(&self) -> usize {
        self.empty.len()
    }

    /// Total one-hot dimensionality (what the equivalent CSR's `cols()`
    /// reports and what model weight vectors are sized to).
    pub fn cols(&self) -> usize {
        self.dim
    }

    /// Samples (active columns) per non-empty row.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Active entries over the whole matrix: `k` per non-empty row.
    pub fn nnz(&self) -> usize {
        self.k * self.empty.iter().filter(|&&e| !e).count()
    }

    pub fn is_empty_row(&self, i: usize) -> bool {
        self.empty[i]
    }

    /// Row `i`'s strictly-increasing absolute column codes; the empty
    /// slice for an empty input row.
    #[inline]
    pub fn codes_of(&self, i: usize) -> &[u32] {
        if self.empty[i] {
            &[]
        } else {
            &self.codes[i * self.k..(i + 1) * self.k]
        }
    }

    /// Export to the legacy CSR representation (all stored values 1.0)
    /// — bit-identical to what `Expansion::expand` builds for the same
    /// samples. Compatibility path for LIBSVM IO and CSR consumers; the
    /// learning layer trains on the codes directly.
    pub fn to_csr(&self) -> Csr {
        let ones = vec![1.0f32; self.k];
        let mut b = CsrBuilder::new(self.dim);
        for i in 0..self.rows() {
            let codes = self.codes_of(i);
            b.push_sorted_row(codes, &ones[..codes.len()]);
        }
        b.finish()
    }

    /// Validate structural invariants (used by property/parity tests):
    /// sample `j`'s code must land in column block `j`, which also
    /// forces strict monotonicity within each row.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.k == 0 || self.dim % self.k != 0 {
            return Err(format!("dim {} not a multiple of k {}", self.dim, self.k));
        }
        if self.codes.len() != self.empty.len() * self.k {
            return Err("codes slab length disagrees with rows × k".into());
        }
        let code_space = self.dim / self.k;
        for i in 0..self.rows() {
            for (j, &c) in self.codes_of(i).iter().enumerate() {
                let (lo, hi) = (j * code_space, (j + 1) * code_space);
                if !(lo..hi).contains(&(c as usize)) {
                    return Err(format!("row {i} sample {j}: code {c} outside block [{lo},{hi})"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::{CwsHasher, CwsSample};
    use crate::features::Expansion;

    fn samples_for(rows: &[&[f32]], k: usize, seed: u64) -> Vec<Option<Vec<CwsSample>>> {
        let h = CwsHasher::new(seed, k);
        rows.iter()
            .map(|r| {
                if r.iter().any(|&v| v > 0.0) {
                    Some(h.hash_dense(r))
                } else {
                    None
                }
            })
            .collect()
    }

    #[test]
    fn encode_to_csr_matches_expand_exactly() {
        let e = Expansion::new(16, 6);
        let s = samples_for(
            &[&[1.0f32, 0.5, 2.0], &[0.0f32, 0.0, 0.0], &[3.0f32, 0.0, 0.1]],
            16,
            7,
        );
        let cm = e.encode(&s);
        cm.check_invariants().unwrap();
        assert_eq!(cm.to_csr(), e.expand(&s));
        assert_eq!(cm.rows(), 3);
        assert_eq!(cm.cols(), e.dim());
        assert_eq!(cm.k(), 16);
        assert_eq!(cm.nnz(), 32); // two live rows × k
    }

    #[test]
    fn empty_rows_are_masked() {
        let e = Expansion::new(8, 4);
        let s = samples_for(&[&[0.0f32, 0.0], &[1.0f32, 2.0]], 8, 3);
        let cm = e.encode(&s);
        assert!(cm.is_empty_row(0));
        assert!(!cm.is_empty_row(1));
        assert!(cm.codes_of(0).is_empty());
        assert_eq!(cm.codes_of(1).len(), 8);
        assert_eq!(cm.to_csr().row(0).nnz(), 0);
    }

    #[test]
    fn codes_are_block_aligned_and_increasing() {
        let e = Expansion::new(32, 5).with_t_bits(2).unwrap();
        let s = samples_for(&[&[0.4f32, 1.7, 0.0, 2.2]], 32, 11);
        let cm = e.encode(&s);
        cm.check_invariants().unwrap();
        let codes = cm.codes_of(0);
        assert!(codes.windows(2).all(|w| w[0] < w[1]));
        for (j, &c) in codes.iter().enumerate() {
            assert_eq!(c as usize / e.code_space(), j);
        }
    }
}
