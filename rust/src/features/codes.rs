//! Dense one-hot **code matrix** — the fast-path representation of
//! hashed features.
//!
//! The §4 expansion produces exactly one active column per `(row,
//! sample)` pair, so a CSR with `k` ones per row stores three arrays
//! (indptr, indices, values) to say what a dense `[n × k]` slab of
//! `u32` column codes says alone. [`CodeMatrix`] is that slab plus an
//! empty-row mask: ~3× less memory traffic than the CSR (no `f32`
//! values, no indptr), and the learning layer's inner products collapse
//! to `k` gathers with no multiplies (see `svm::rowset`).
//!
//! Built by [`crate::features::Expansion::encode`]; [`CodeMatrix::to_csr`]
//! is the compatibility/export path (LIBSVM IO, CSR-consuming code) and
//! reproduces `Expansion::expand` exactly.
//!
//! [`PackedCodes`] compresses the slab further for the serving tier: a
//! row's k codes at b bits each packed into contiguous `u64` words —
//! the b-bit minwise footprint argument (arXiv:1105.4385) applied to
//! the serving memory stream. Lossless whenever `b = b_i + b_t` divides
//! 64 (the 4/8/16-bit configurations the serving path cares about),
//! because a code's block offset `j · 2^b` is recoverable from its slot
//! position alone.

use crate::data::sparse::{Csr, CsrBuilder};

/// `[n × k]` one-hot column codes, row-major, with an empty-row mask.
///
/// Row `i`'s `k` codes are absolute column indices into the
/// `k · 2^{b_i+b_t}`-dimensional one-hot space — sample `j`'s code
/// lives in block `j`, so each row's codes are strictly increasing.
/// Rows hashed from an all-zero input vector (no samples) are marked
/// empty and behave as all-zero feature rows everywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct CodeMatrix {
    k: usize,
    /// One-hot dimensionality `k · 2^{b_i+b_t}` (the CSR `cols()`).
    dim: usize,
    /// Row-major `[n × k]` absolute column codes; empty rows hold zeros.
    codes: Vec<u32>,
    /// Per-row marker for empty input vectors.
    empty: Vec<bool>,
}

impl CodeMatrix {
    pub(crate) fn from_parts(k: usize, dim: usize, codes: Vec<u32>, empty: Vec<bool>) -> Self {
        debug_assert!(k > 0 && dim % k == 0);
        debug_assert_eq!(codes.len(), empty.len() * k);
        Self { k, dim, codes, empty }
    }

    pub fn rows(&self) -> usize {
        self.empty.len()
    }

    /// Total one-hot dimensionality (what the equivalent CSR's `cols()`
    /// reports and what model weight vectors are sized to).
    pub fn cols(&self) -> usize {
        self.dim
    }

    /// Samples (active columns) per non-empty row.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Active entries over the whole matrix: `k` per non-empty row.
    pub fn nnz(&self) -> usize {
        self.k * self.empty.iter().filter(|&&e| !e).count()
    }

    pub fn is_empty_row(&self, i: usize) -> bool {
        self.empty[i]
    }

    /// Row `i`'s strictly-increasing absolute column codes; the empty
    /// slice for an empty input row.
    #[inline]
    pub fn codes_of(&self, i: usize) -> &[u32] {
        if self.empty[i] {
            &[]
        } else {
            &self.codes[i * self.k..(i + 1) * self.k]
        }
    }

    /// Export to the legacy CSR representation (all stored values 1.0)
    /// — bit-identical to what `Expansion::expand` builds for the same
    /// samples. Compatibility path for LIBSVM IO and CSR consumers; the
    /// learning layer trains on the codes directly.
    pub fn to_csr(&self) -> Csr {
        let ones = vec![1.0f32; self.k];
        let mut b = CsrBuilder::new(self.dim);
        for i in 0..self.rows() {
            let codes = self.codes_of(i);
            b.push_sorted_row(codes, &ones[..codes.len()]);
        }
        b.finish()
    }

    /// Validate structural invariants (used by property/parity tests):
    /// sample `j`'s code must land in column block `j`, which also
    /// forces strict monotonicity within each row.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.k == 0 || self.dim % self.k != 0 {
            return Err(format!("dim {} not a multiple of k {}", self.dim, self.k));
        }
        if self.codes.len() != self.empty.len() * self.k {
            return Err("codes slab length disagrees with rows × k".into());
        }
        let code_space = self.dim / self.k;
        for i in 0..self.rows() {
            for (j, &c) in self.codes_of(i).iter().enumerate() {
                let (lo, hi) = (j * code_space, (j + 1) * code_space);
                if !(lo..hi).contains(&(c as usize)) {
                    return Err(format!("row {i} sample {j}: code {c} outside block [{lo},{hi})"));
                }
            }
        }
        Ok(())
    }

    /// Pack the slab into b-bit words ([`PackedCodes`]), or `None` when
    /// this matrix's code space has no supported packing width (see
    /// [`PackedCodes::supported_bits`]). Lossless:
    /// [`PackedCodes::to_code_matrix`] reproduces `self` exactly.
    pub fn pack(&self) -> Option<PackedCodes> {
        let code_space = self.dim / self.k;
        let bits = PackedCodes::supported_bits(code_space)?;
        let wpr = PackedCodes::words_per_row(self.k, bits);
        let mut words = vec![0u64; wpr * self.rows()];
        for i in 0..self.rows() {
            if !self.empty[i] {
                let row = &self.codes[i * self.k..(i + 1) * self.k];
                PackedCodes::pack_row(row, code_space, bits, &mut words[i * wpr..(i + 1) * wpr]);
            }
        }
        Some(PackedCodes {
            k: self.k,
            bits,
            dim: self.dim,
            words_per_row: wpr,
            words,
            empty: self.empty.clone(),
        })
    }
}

/// `[n × ⌈k·b/64⌉]` packed b-bit code words — [`CodeMatrix`] with the
/// redundant block offsets stripped.
///
/// A row's sample-`j` code is `j · 2^b + rel` where only the b-bit
/// `rel` varies, so the packed form stores `rel` alone: slot `j` lives
/// in word `j / (64/b)` at bit offset `(j mod 64/b) · b`, and the
/// absolute code is reconstructed from the slot position for free. The
/// last word of a row is zero-padded; empty input rows keep all-zero
/// words plus their mask bit. At `b = 4` this is a 8× smaller stream
/// than the `u32` slab — the difference between a row's codes spilling
/// cache lines and fitting in a couple of registers on the serving hot
/// path (`serve::Scorer::with_packed_codes`).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedCodes {
    k: usize,
    /// Bits per code (`b_i + b_t`); always a divisor of 64.
    bits: u8,
    /// One-hot dimensionality of the unpacked space, `k · 2^bits`.
    dim: usize,
    words_per_row: usize,
    /// Row-major `[n × words_per_row]` packed words.
    words: Vec<u64>,
    /// Per-row marker for empty input vectors.
    empty: Vec<bool>,
}

impl PackedCodes {
    /// The packing width for a code space, or `None` when unsupported.
    /// Supported widths are exactly the power-of-two code spaces whose
    /// bit count divides 64 — b ∈ {1, 2, 4, 8, 16} given the crate's
    /// `MAX_CODE_BITS = 24` cap — so rows never straddle word
    /// boundaries and pack/unpack stay shift-and-mask only.
    pub fn supported_bits(code_space: usize) -> Option<u8> {
        if code_space < 2 || !code_space.is_power_of_two() {
            return None;
        }
        let bits = code_space.trailing_zeros() as u8;
        (64 % bits as usize == 0).then_some(bits)
    }

    /// Words needed for one row of `k` codes at `bits` per code.
    pub fn words_per_row(k: usize, bits: u8) -> usize {
        k.div_ceil(64 / bits as usize)
    }

    /// Pack one row of absolute codes into a pre-zeroed word slice.
    /// `rel = abs & (2^bits − 1)` is exact because `abs = j·2^bits +
    /// rel` keeps the low `bits` untouched by the block offset.
    fn pack_row(codes: &[u32], code_space: usize, bits: u8, out: &mut [u64]) {
        let cpw = 64 / bits as usize;
        let mask = code_space as u64 - 1;
        for (j, &abs) in codes.iter().enumerate() {
            out[j / cpw] |= (abs as u64 & mask) << ((j % cpw) * bits as usize);
        }
    }

    /// Pack one row's absolute codes into a reusable word buffer
    /// (cleared and resized to exactly the row's word count) — the
    /// serving scratch entry point: zero allocations in steady state.
    pub fn pack_row_into(codes: &[u32], code_space: usize, bits: u8, words: &mut Vec<u64>) {
        let cpw = 64 / bits as usize;
        words.clear();
        words.resize(codes.len().div_ceil(cpw), 0);
        Self::pack_row(codes, code_space, bits, words);
    }

    /// Decode sample `j`'s **absolute** code from a packed row slice.
    #[inline]
    pub fn unpack_abs(words: &[u64], code_space: usize, bits: u8, j: usize) -> u32 {
        let cpw = 64 / bits as usize;
        let rel = (words[j / cpw] >> ((j % cpw) * bits as usize)) & (code_space as u64 - 1);
        (j * code_space) as u32 + rel as u32
    }

    pub fn rows(&self) -> usize {
        self.empty.len()
    }

    /// Samples per non-empty row.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Bits per packed code.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// One-hot dimensionality of the unpacked space.
    pub fn cols(&self) -> usize {
        self.dim
    }

    /// Per-sample code space `2^bits`.
    pub fn code_space(&self) -> usize {
        1usize << self.bits
    }

    pub fn is_empty_row(&self, i: usize) -> bool {
        self.empty[i]
    }

    /// Row `i`'s packed words (zero-padded tail; all-zero for empty
    /// rows — check [`Self::is_empty_row`] before decoding).
    #[inline]
    pub fn word_row(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Decode row `i` into `out` as absolute codes (cleared first; left
    /// empty for an empty input row) — mirrors
    /// [`CodeMatrix::codes_of`] semantics on a reusable buffer.
    pub fn unpack_row_into(&self, i: usize, out: &mut Vec<u32>) {
        out.clear();
        if self.empty[i] {
            return;
        }
        let row = self.word_row(i);
        let cs = self.code_space();
        out.extend((0..self.k).map(|j| Self::unpack_abs(row, cs, self.bits, j)));
    }

    /// Pack engine sketch output straight into the word slab — the LSH
    /// build path: at a million rows the intermediate `u32`
    /// [`CodeMatrix`] would be `4k` bytes per row of pure copy traffic,
    /// so this skips it. Produces exactly
    /// `Expansion::checked(k, bits, 0).encode(samples).pack()`: the
    /// 0-bit relative code is `i* mod 2^bits` (the block offset
    /// `j · 2^bits` contributes nothing modulo the code space). `None`
    /// rows become empty-masked all-zero rows; returns `None` when
    /// `bits` has no supported packing.
    pub(crate) fn from_samples(
        samples: &[Option<Vec<crate::cws::CwsSample>>],
        k: usize,
        bits: u8,
    ) -> Option<PackedCodes> {
        let code_space = 1usize << bits;
        if Self::supported_bits(code_space) != Some(bits) {
            return None;
        }
        let wpr = Self::words_per_row(k, bits);
        let cpw = 64 / bits as usize;
        let mask = code_space as u64 - 1;
        let mut words = vec![0u64; wpr * samples.len()];
        let mut empty = vec![false; samples.len()];
        for (i, row) in samples.iter().enumerate() {
            match row {
                Some(s) => {
                    debug_assert_eq!(s.len(), k, "row {i} has {} samples, want {k}", s.len());
                    let out = &mut words[i * wpr..(i + 1) * wpr];
                    for (j, smp) in s.iter().enumerate() {
                        out[j / cpw] |= (smp.i_star as u64 & mask) << ((j % cpw) * bits as usize);
                    }
                }
                None => empty[i] = true,
            }
        }
        Some(PackedCodes { k, bits, dim: k * code_space, words_per_row: wpr, words, empty })
    }

    /// Reconstruct the unpacked [`CodeMatrix`] — the lossless inverse
    /// of [`CodeMatrix::pack`] (pinned by the roundtrip property test).
    pub fn to_code_matrix(&self) -> CodeMatrix {
        let mut codes = vec![0u32; self.rows() * self.k];
        let cs = self.code_space();
        for i in 0..self.rows() {
            if !self.empty[i] {
                let row = self.word_row(i);
                for (j, slot) in codes[i * self.k..(i + 1) * self.k].iter_mut().enumerate() {
                    *slot = Self::unpack_abs(row, cs, self.bits, j);
                }
            }
        }
        CodeMatrix::from_parts(self.k, self.dim, codes, self.empty.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cws::sampler::{CwsHasher, CwsSample};
    use crate::features::Expansion;

    fn samples_for(rows: &[&[f32]], k: usize, seed: u64) -> Vec<Option<Vec<CwsSample>>> {
        let h = CwsHasher::new(seed, k);
        rows.iter()
            .map(|r| {
                if r.iter().any(|&v| v > 0.0) {
                    Some(h.hash_dense(r))
                } else {
                    None
                }
            })
            .collect()
    }

    #[test]
    fn encode_to_csr_matches_expand_exactly() {
        let e = Expansion::new(16, 6);
        let s = samples_for(
            &[&[1.0f32, 0.5, 2.0], &[0.0f32, 0.0, 0.0], &[3.0f32, 0.0, 0.1]],
            16,
            7,
        );
        let cm = e.encode(&s);
        cm.check_invariants().unwrap();
        assert_eq!(cm.to_csr(), e.expand(&s));
        assert_eq!(cm.rows(), 3);
        assert_eq!(cm.cols(), e.dim());
        assert_eq!(cm.k(), 16);
        assert_eq!(cm.nnz(), 32); // two live rows × k
    }

    #[test]
    fn empty_rows_are_masked() {
        let e = Expansion::new(8, 4);
        let s = samples_for(&[&[0.0f32, 0.0], &[1.0f32, 2.0]], 8, 3);
        let cm = e.encode(&s);
        assert!(cm.is_empty_row(0));
        assert!(!cm.is_empty_row(1));
        assert!(cm.codes_of(0).is_empty());
        assert_eq!(cm.codes_of(1).len(), 8);
        assert_eq!(cm.to_csr().row(0).nnz(), 0);
    }

    #[test]
    fn codes_are_block_aligned_and_increasing() {
        let e = Expansion::new(32, 5).with_t_bits(2).unwrap();
        let s = samples_for(&[&[0.4f32, 1.7, 0.0, 2.2]], 32, 11);
        let cm = e.encode(&s);
        cm.check_invariants().unwrap();
        let codes = cm.codes_of(0);
        assert!(codes.windows(2).all(|w| w[0] < w[1]));
        for (j, &c) in codes.iter().enumerate() {
            assert_eq!(c as usize / e.code_space(), j);
        }
    }

    #[test]
    fn supported_bits_are_exactly_the_word_aligned_widths() {
        assert_eq!(PackedCodes::supported_bits(2), Some(1));
        assert_eq!(PackedCodes::supported_bits(4), Some(2));
        assert_eq!(PackedCodes::supported_bits(16), Some(4));
        assert_eq!(PackedCodes::supported_bits(256), Some(8));
        assert_eq!(PackedCodes::supported_bits(1 << 16), Some(16));
        // 3/5/6-bit codes straddle word boundaries; not supported.
        assert_eq!(PackedCodes::supported_bits(8), None);
        assert_eq!(PackedCodes::supported_bits(32), None);
        assert_eq!(PackedCodes::supported_bits(64), None);
        // Degenerate / non-power-of-two spaces.
        assert_eq!(PackedCodes::supported_bits(0), None);
        assert_eq!(PackedCodes::supported_bits(1), None);
        assert_eq!(PackedCodes::supported_bits(48), None);
    }

    #[test]
    fn pack_is_a_lossless_roundtrip() {
        // Property: for every supported (b_i, b_t) width, pack →
        // to_code_matrix reproduces the CodeMatrix exactly (empty rows
        // included), and the streaming row entry points agree with the
        // slab ones.
        crate::util::prop::check("packed-codes-roundtrip", 60, |g| {
            let k = g.usize_in(1, 48);
            let &(i_bits, t_bits) = g.choose(&[(4u8, 0u8), (2, 2), (8, 0), (4, 4), (8, 8)]);
            let e = Expansion::new(k, i_bits).with_t_bits(t_bits).map_err(|x| x.to_string())?;
            let dim = g.usize_in(2, 24);
            let rows: Vec<Vec<f32>> =
                (0..g.usize_in(1, 12)).map(|_| g.nonneg_vec(dim, g.rng.uniform())).collect();
            let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let s = samples_for(&refs, k, 21);
            let cm = e.encode(&s);
            let packed = cm.pack().ok_or("supported width must pack")?;
            crate::util::prop::ensure(
                packed.bits() == i_bits + t_bits,
                "packed width is b_i + b_t",
            )?;
            crate::util::prop::ensure(packed.to_code_matrix() == cm, "pack/unpack roundtrip")?;
            let mut buf = Vec::new();
            let mut words = Vec::new();
            for i in 0..cm.rows() {
                packed.unpack_row_into(i, &mut buf);
                crate::util::prop::ensure(buf == cm.codes_of(i), "unpack_row_into == codes_of")?;
                PackedCodes::pack_row_into(
                    cm.codes_of(i),
                    e.code_space(),
                    packed.bits(),
                    &mut words,
                );
                let want: &[u64] =
                    if cm.is_empty_row(i) { &[] } else { packed.word_row(i) };
                crate::util::prop::ensure(words == want, "pack_row_into == slab words")?;
            }
            Ok(())
        });
    }

    #[test]
    fn unsupported_widths_do_not_pack() {
        let e = Expansion::new(8, 3);
        let s = samples_for(&[&[1.0f32, 2.0]], 8, 5);
        assert!(e.encode(&s).pack().is_none(), "3-bit codes must not pack");
    }

    #[test]
    fn from_samples_equals_encode_then_pack() {
        // The direct sample→slab path (the LSH build) must produce the
        // identical PackedCodes as the layered encode().pack() route,
        // empty rows and tail padding included.
        for bits in [1u8, 2, 4, 8, 16] {
            for k in [1usize, 5, 8, 13, 64] {
                let rows: Vec<Vec<f32>> = vec![
                    vec![1.0, 0.5, 2.0, 0.0, 0.3],
                    vec![0.0; 5],
                    vec![0.2, 0.0, 0.0, 4.0, 1.5],
                ];
                let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
                let s = samples_for(&refs, k, 31);
                let direct = PackedCodes::from_samples(&s, k, bits).expect("supported width");
                let layered =
                    Expansion::new(k, bits).encode(&s).pack().expect("supported width");
                assert_eq!(direct, layered, "bits={bits} k={k}");
            }
        }
        assert!(PackedCodes::from_samples(&[], 4, 3).is_none(), "3-bit must not pack");
    }
}
