//! CWS hashing benchmarks — the paper's core cost (Figures 4–8 all sit
//! on top of this loop) and the §Perf L1/L3 comparison point.
//!
//! Run: `cargo bench --bench bench_cws [-- --quick]`

use minmax::bench::{black_box, Runner};
use minmax::cws::{materialize_params, CwsHasher};
use minmax::data::dense::Dense;
use minmax::data::sparse::Csr;
use minmax::features::Expansion;
use minmax::util::rng::Pcg64;

fn random_dense(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Dense {
    let mut rng = Pcg64::new(seed);
    let mut d = Dense::from_vec(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.lognormal(0.0, 1.0) as f32
                }
            })
            .collect(),
    );
    for i in 0..rows {
        if !d.row(i).iter().any(|&v| v > 0.0) {
            d.row_mut(i)[0] = 1.0;
        }
    }
    d
}

fn main() {
    let mut r = Runner::new();

    // Dense hashing across (D, k) shapes: cost is O(D·k) cells.
    for (d, k) in [(64usize, 64usize), (256, 128), (1024, 256)] {
        let x = random_dense(1, d, 0.0, 1);
        let h = CwsHasher::new(7, k);
        r.bench_with_throughput(
            &format!("hash-dense/D{d}/k{k}"),
            Some(((d * k) as f64, "cell")),
            || {
                black_box(h.hash_dense(x.row(0)));
            },
        );
    }

    // Amortized dense batch hashing (the service hot path).
    for (d, k) in [(256usize, 128usize), (1024, 256)] {
        let x = random_dense(1, d, 0.0, 1);
        let h = CwsHasher::new(7, k).dense_batch(d);
        r.bench_with_throughput(
            &format!("hash-batch/D{d}/k{k}"),
            Some(((d * k) as f64, "cell")),
            || {
                black_box(h.hash(x.row(0)));
            },
        );
    }

    // Sparse hashing: only nonzeros pay.
    let sp = Csr::from_dense(&random_dense(1, 65536, 0.995, 2));
    let h = CwsHasher::new(7, 128);
    r.bench_with_throughput(
        &format!("hash-sparse/nnz{}/k128", sp.nnz()),
        Some(((sp.nnz() * 128) as f64, "cell")),
        || {
            black_box(h.hash_sparse(sp.row(0)));
        },
    );

    // Parameter materialization (PJRT setup cost, once per service).
    r.bench_with_throughput(
        "materialize-params/D256/k128",
        Some(((256 * 128) as f64, "cell")),
        || {
            black_box(materialize_params(3, 256, 128));
        },
    );

    // Feature expansion (0-bit codes -> sparse one-hot).
    let x = random_dense(1, 256, 0.3, 3);
    let h2 = CwsHasher::new(9, 256);
    let samples = h2.hash_dense(x.row(0));
    let e = Expansion::new(256, 8);
    r.bench_with_throughput("expand/k256/b8", Some((256.0, "sample")), || {
        black_box(e.expand_row(&samples));
    });

    r.save("bench_cws");
}
