//! SVM solver benchmarks — the training side of Table 1 (precomputed
//! kernel SVM) and Figures 7–8 (linear SVM on hashed features), plus
//! the CodeMatrix-vs-CSR train-path comparison the learning-layer fast
//! path is judged by (EXPERIMENTS.md §Perf, train-side rows):
//!
//! * `linear-svm/train/n300/k128b8` — dual-CD over the legacy CSR
//!   expansion (index + value loads, converts, multiplies);
//! * `linear-svm/train-codes/n300/k128b8` — the same solve over the
//!   one-hot `CodeMatrix` (gathers only; bit-identical predictions,
//!   pinned by `tests/svm_parity.rs`);
//! * `ovr/train-par/...` — one-vs-rest over the codes at 1 thread vs
//!   `MINMAX_THREADS` (classes are embarrassingly parallel).
//!
//! Run: `cargo bench --bench bench_svm [-- --quick]`; CI uploads the
//! JSON as `BENCH_svm.json`.

use minmax::bench::{black_box, Runner};
use minmax::coordinator::{hash_dataset, PipelineConfig};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Matrix;
use minmax::kernels::matrix::kernel_matrix_sym;
use minmax::kernels::KernelKind;
use minmax::svm::{KernelSvmParams, LinearOvR, LinearSvmParams};
use minmax::util::pool;

fn main() {
    let mut r = Runner::new();

    // Binary kernel-SVM training on a precomputed Gram (n=256).
    let ds = generate("ijcnn", SynthConfig { seed: 1, n_train: 256, n_test: 10 }).unwrap();
    let gram = kernel_matrix_sym(KernelKind::MinMax, &ds.train_x);
    let y: Vec<i32> = ds.train_y.iter().map(|&c| if c == 0 { 1 } else { -1 }).collect();
    r.bench_with_throughput("kernel-svm/train/n256", Some((256.0, "row")), || {
        black_box(minmax::svm::kernel::train_binary(
            &gram,
            &y,
            &KernelSvmParams { c: 1.0, ..Default::default() },
        ));
    });

    // Gram computation itself (dominates the Table-1 protocol).
    r.bench_with_throughput(
        "kernel-svm/gram/minmax/n256xD24",
        Some(((256 * 257 / 2) as f64, "pair")),
        || {
            black_box(kernel_matrix_sym(KernelKind::MinMax, &ds.train_x));
        },
    );

    // Linear SVM on hashed CWS features (Figure 7's inner loop): the
    // same workload through both training representations. The
    // acceptance ratio is train-codes/train nnz-per-second.
    let ds2 = generate("letter", SynthConfig { seed: 2, n_train: 300, n_test: 10 }).unwrap();
    let hashed = hash_dataset(&ds2, &PipelineConfig::new(3, 128, 8)).unwrap();
    let train_csr = hashed.train_csr();
    let nnz = hashed.train.nnz() as f64;
    let y2: Vec<i32> = ds2.train_y.iter().map(|&c| if c == 0 { 1 } else { -1 }).collect();
    let lp = LinearSvmParams { c: 1.0, ..Default::default() };
    r.bench_with_throughput("linear-svm/train/n300/k128b8", Some((nnz, "nnz")), || {
        black_box(minmax::svm::linear::train_binary(&train_csr, &y2, &lp));
    });
    r.bench_with_throughput("linear-svm/train-codes/n300/k128b8", Some((nnz, "nnz")), || {
        black_box(minmax::svm::linear::train_binary(&hashed.train, &y2, &lp));
    });

    // One-vs-rest over the code matrix: sequential baseline vs the
    // pool's thread count (set MINMAX_THREADS to pin it; identical
    // models either way).
    let classes = ds2.n_classes();
    let ovr_work = nnz * classes as f64;
    r.bench_with_throughput("ovr/train-par/n300/k128b8/t1", Some((ovr_work, "nnz")), || {
        black_box(LinearOvR::train_with_threads(&hashed.train, &ds2.train_y, classes, &lp, 1));
    });
    // Skip the parallel row on single-core hosts: it would duplicate
    // the t1 name in the JSON and measure the same inline fallback.
    let threads = pool::default_threads();
    if threads > 1 {
        r.bench_with_throughput(
            &format!("ovr/train-par/n300/k128b8/t{threads}"),
            Some((ovr_work, "nnz")),
            || {
                black_box(LinearOvR::train_with_threads(
                    &hashed.train,
                    &ds2.train_y,
                    classes,
                    &lp,
                    threads,
                ));
            },
        );
    }

    // Full hashed pipeline step: hash + encode (Figure 7 outer loop;
    // name kept stable across the CSR→CodeMatrix switch so the perf
    // trajectory stays diffable).
    let dsm = match &ds2.train_x {
        Matrix::Dense(d) => d.clone(),
        _ => unreachable!(),
    };
    r.bench_with_throughput(
        "pipeline/hash+expand/n300/k128",
        Some(((dsm.rows() * dsm.cols() * 128) as f64, "cell")),
        || {
            black_box(hash_dataset(&ds2, &PipelineConfig::new(4, 128, 8)));
        },
    );

    r.save("bench_svm");
}
