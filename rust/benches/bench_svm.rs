//! SVM solver benchmarks — the training side of Table 1 (precomputed
//! kernel SVM) and Figures 7–8 (linear SVM on hashed features).
//!
//! Run: `cargo bench --bench bench_svm [-- --quick]`

use minmax::bench::{black_box, Runner};
use minmax::coordinator::{hash_dataset, PipelineConfig};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Matrix;
use minmax::kernels::matrix::kernel_matrix_sym;
use minmax::kernels::KernelKind;
use minmax::svm::{KernelSvmParams, LinearSvmParams};

fn main() {
    let mut r = Runner::new();

    // Binary kernel-SVM training on a precomputed Gram (n=256).
    let ds = generate("ijcnn", SynthConfig { seed: 1, n_train: 256, n_test: 10 }).unwrap();
    let gram = kernel_matrix_sym(KernelKind::MinMax, &ds.train_x);
    let y: Vec<i32> = ds.train_y.iter().map(|&c| if c == 0 { 1 } else { -1 }).collect();
    r.bench_with_throughput("kernel-svm/train/n256", Some((256.0, "row")), || {
        black_box(minmax::svm::kernel::train_binary(
            &gram,
            &y,
            &KernelSvmParams { c: 1.0, ..Default::default() },
        ));
    });

    // Gram computation itself (dominates the Table-1 protocol).
    r.bench_with_throughput(
        "kernel-svm/gram/minmax/n256xD24",
        Some(((256 * 257 / 2) as f64, "pair")),
        || {
            black_box(kernel_matrix_sym(KernelKind::MinMax, &ds.train_x));
        },
    );

    // Linear SVM on hashed CWS features (Figure 7's inner loop).
    let ds2 = generate("letter", SynthConfig { seed: 2, n_train: 300, n_test: 10 }).unwrap();
    let hashed = hash_dataset(&ds2, &PipelineConfig::new(3, 128, 8)).unwrap();
    let y2: Vec<i32> = ds2.train_y.iter().map(|&c| if c == 0 { 1 } else { -1 }).collect();
    r.bench_with_throughput(
        "linear-svm/train/n300/k128b8",
        Some(((300 * 128) as f64, "nnz"),),
        || {
            black_box(minmax::svm::linear::train_binary(
                &hashed.train,
                &y2,
                &LinearSvmParams { c: 1.0, ..Default::default() },
            ));
        },
    );

    // Full hashed pipeline step: hash + expand (Figure 7 outer loop).
    let dsm = match &ds2.train_x {
        Matrix::Dense(d) => d.clone(),
        _ => unreachable!(),
    };
    r.bench_with_throughput(
        "pipeline/hash+expand/n300/k128",
        Some(((dsm.rows() * dsm.cols() * 128) as f64, "cell")),
        || {
            black_box(hash_dataset(&ds2, &PipelineConfig::new(4, 128, 8)));
        },
    );

    r.save("bench_svm");
}
