//! Cluster saturation bench: throughput scaling across shard counts,
//! open-loop overload behaviour (bounded queues + load shedding must
//! keep p99 finite), and hot-swap-under-load loss accounting.
//!
//! Rows / stats:
//! * `cluster-batch/S{n}/*` — closed-loop `score_batch_blocking`
//!   rows/s at 1, 2, 4 shards (the near-linear-scaling claim);
//! * `fused-batch-T1/*` — the single-threaded fused batch path, the
//!   zero-queue baseline the 1-shard cluster pays overhead against;
//! * `open-loop/S{n}/*` stats — offered vs completed rps, merged
//!   histogram p50/p99 (must stay finite under overload), shed and
//!   rejected counts against a deliberately tiny queue;
//! * `hot-swap/S{n}/*` stats — swaps published under full load, with
//!   lost-request count (must be 0);
//! * `fault/S4/{baseline,fault5}/*` stats — the same open-loop shape
//!   healthy vs a 5% injected-panic / 0.5% worker-death plan: what
//!   panic isolation + supervision cost in completed throughput and
//!   p99 when 1-in-20 requests poisons its worker (DESIGN.md §2.9).
//!
//! Run: `cargo bench --bench bench_coordinator [-- --quick]`; CI
//! uploads `results/bench/bench_coordinator.json` as
//! BENCH_coordinator.json.

use std::time::{Duration, Instant};

use minmax::bench::{black_box, Runner};
use minmax::coordinator::{
    silence_injected_panics, ClusterConfig, ClusterError, FaultPlan, ScoreRouter,
};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Dense;
use minmax::pipeline::Pipeline;
use minmax::serve::Scorer;

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("MINMAX_BENCH_QUICK").is_ok()
}

/// Wait until every accepted request has been answered — completed,
/// deadline-expired, or isolated as a worker panic (bounded, so a bug
/// cannot hang the bench).
fn drain(cluster: &ScoreRouter) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let s = cluster.snapshot();
        if s.answered() >= s.accepted() {
            return;
        }
        assert!(Instant::now() < deadline, "cluster failed to drain: {}", s.render());
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn main() {
    let mut r = Runner::new();
    let quick = quick();

    // Paper-scale serving shape: k=128 samples, b=8 codes, D=64.
    let ds = generate("usps", SynthConfig { seed: 3, n_train: 300, n_test: 512 })
        .expect("synth dataset");
    let mut pipe =
        Pipeline::builder().seed(5).samples(128).i_bits(8).build().expect("build pipeline");
    pipe.fit(&ds.train_x, &ds.train_y).expect("fit");
    let scorer = pipe.scorer(ds.dim()).expect("scorer");
    let baseline = scorer.predict_batch_with_threads(&ds.test_x, 1);
    let n = ds.test_x.rows();
    let tag = format!("usps/D{}/k128/b8", ds.dim());
    let dense: Dense = ds.test_x.to_dense();

    // Zero-queue baseline for the scaling comparison.
    r.bench_with_throughput(&format!("fused-batch-T1/{tag}"), Some((n as f64, "row")), || {
        black_box(scorer.predict_batch_with_threads(&ds.test_x, 1));
    });

    // ---- Closed-loop batch scaling across shard counts -------------
    for shards in [1usize, 2, 4] {
        let cluster = ScoreRouter::start(
            scorer.clone(),
            ClusterConfig {
                shards,
                queue_cap: 1024,
                shed_watermark: None,
                steal: true,
                faults: None,
            },
        )
        .expect("start cluster");
        // Parity guard before timing: the cluster must compute the
        // same answers as the path it scales out.
        assert_eq!(cluster.score_batch_blocking(&ds.test_x).unwrap(), baseline);
        r.bench_with_throughput(
            &format!("cluster-batch/S{shards}/{tag}"),
            Some((n as f64, "row")),
            || {
                black_box(cluster.score_batch_blocking(&ds.test_x).unwrap());
            },
        );
        cluster.shutdown();
    }

    // ---- Open-loop saturation against a tiny bounded queue ---------
    // Fire-and-forget submits (response handles dropped — the cluster
    // tolerates absent receivers) against queue_cap=64, shed
    // watermark 48: the queue must stay bounded, overload must shed,
    // and the latency histogram must keep p99 finite.
    let window = if quick { Duration::from_millis(300) } else { Duration::from_secs(2) };
    for shards in [1usize, 4] {
        let cluster = ScoreRouter::start(
            scorer.clone(),
            ClusterConfig {
                shards,
                queue_cap: 64,
                shed_watermark: Some(48),
                steal: true,
                faults: None,
            },
        )
        .expect("start cluster");
        let start = Instant::now();
        let mut offered = 0u64;
        let mut shed = 0u64;
        let mut rejected = 0u64;
        while start.elapsed() < window {
            match cluster.submit(offered, dense.row((offered as usize) % n)) {
                Ok(sub) => drop(sub),
                Err(ClusterError::Shed { .. }) => shed += 1,
                Err(ClusterError::QueueFull) => rejected += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
            offered += 1;
        }
        drain(&cluster);
        let snap = cluster.snapshot();
        let secs = start.elapsed().as_secs_f64();
        assert_eq!(snap.completed, snap.accepted(), "open loop lost requests");
        assert_eq!(snap.shed, shed);
        assert!(
            snap.latency_p99_ms.is_finite(),
            "p99 must stay finite under overload: {}",
            snap.render()
        );
        r.stat(&format!("open-loop/S{shards}/offered-rps"), offered as f64 / secs, "req/s");
        r.stat(
            &format!("open-loop/S{shards}/completed-rps"),
            snap.completed as f64 / secs,
            "req/s",
        );
        r.stat(&format!("open-loop/S{shards}/p50-ms"), snap.latency_p50_ms, "ms");
        r.stat(&format!("open-loop/S{shards}/p99-ms"), snap.latency_p99_ms, "ms");
        r.stat(&format!("open-loop/S{shards}/shed"), shed as f64, "req");
        r.stat(&format!("open-loop/S{shards}/rejected"), rejected as f64, "req");
        cluster.shutdown();
    }

    // ---- Hot swap under full load ----------------------------------
    // Publish fresh versions while an open-loop submitter saturates
    // the queues; every accepted request must complete (zero lost),
    // and completions must be tallied under the versions that ran.
    let swaps = if quick { 5usize } else { 25 };
    for shards in [1usize, 4] {
        let cluster = ScoreRouter::start(
            scorer.clone(),
            ClusterConfig {
                shards,
                queue_cap: 256,
                shed_watermark: None,
                steal: true,
                faults: None,
            },
        )
        .expect("start cluster");
        let republished: Scorer = scorer.clone();
        std::thread::scope(|s| {
            let publisher = s.spawn(|| {
                for _ in 0..swaps {
                    cluster.publish(republished.clone()).expect("publish");
                    std::thread::sleep(Duration::from_millis(if quick { 2 } else { 10 }));
                }
            });
            let mut i = 0u64;
            while !publisher.is_finished() {
                match cluster.submit(i, dense.row((i as usize) % n)) {
                    Ok(sub) => drop(sub),
                    Err(ClusterError::QueueFull) => std::thread::yield_now(),
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                i += 1;
            }
            publisher.join().unwrap();
        });
        drain(&cluster);
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, snap.accepted(), "hot swap lost requests: {}", snap.render());
        let lost = snap.accepted().saturating_sub(snap.completed);
        assert_eq!(snap.current_version, 1 + swaps as u64);
        let tallied: u64 = snap.version_counts.iter().map(|&(_, c)| c).sum();
        assert_eq!(tallied, snap.completed);
        r.stat(&format!("hot-swap/S{shards}/swaps"), swaps as f64, "swap");
        r.stat(&format!("hot-swap/S{shards}/completed"), snap.completed as f64, "req");
        r.stat(&format!("hot-swap/S{shards}/lost"), lost as f64, "req");
        r.stat(
            &format!("hot-swap/S{shards}/versions-served"),
            snap.version_counts.len() as f64,
            "version",
        );
        cluster.shutdown();
    }

    // ---- Fault-rate overhead (panic isolation + supervision) -------
    // The open-loop shape again at 4 shards, healthy vs a 5%
    // injected-panic / 0.5% worker-death plan. Plans are passed
    // programmatically through `ClusterConfig::faults` — the env
    // gating in `FaultPlan::from_env` only covers debug builds, and
    // this bench runs in release. The rows answer: what do the unwind
    // boundary and supervisor respawns cost in completed throughput
    // and p99 when 1-in-20 requests poisons its worker?
    silence_injected_panics();
    let fault5 = FaultPlan {
        seed: 0xC0FFEE,
        panic_rate: 0.05,
        death_rate: 0.005,
        slow_rate: 0.0,
        slow: Duration::ZERO,
        stall_rate: 0.0,
        stall: Duration::ZERO,
    };
    for (label, faults) in [("baseline", None), ("fault5", Some(fault5))] {
        let injected = faults.is_some();
        let cluster = ScoreRouter::start(
            scorer.clone(),
            ClusterConfig { shards: 4, queue_cap: 1024, shed_watermark: None, steal: true, faults },
        )
        .expect("start cluster");
        let start = Instant::now();
        let mut i = 0u64;
        while start.elapsed() < window {
            match cluster.submit(i, dense.row((i as usize) % n)) {
                Ok(sub) => drop(sub),
                Err(ClusterError::QueueFull) => std::thread::yield_now(),
                Err(e) => panic!("unexpected submit error: {e}"),
            }
            i += 1;
        }
        drain(&cluster);
        let snap = cluster.snapshot();
        let secs = start.elapsed().as_secs_f64();
        assert!(snap.reconciles(), "fault leg must reconcile: {}", snap.render());
        assert!(
            snap.latency_p99_ms.is_finite(),
            "p99 must stay finite under injected faults: {}",
            snap.render()
        );
        if injected {
            assert!(snap.panicked > 0, "5% plan must actually inject: {}", snap.render());
        } else {
            assert_eq!(snap.panicked, 0, "healthy leg saw a panic: {}", snap.render());
            assert_eq!(snap.restarts, 0, "healthy leg respawned a worker: {}", snap.render());
        }
        r.stat(
            &format!("fault/S4/{label}/completed-rps"),
            snap.completed as f64 / secs,
            "req/s",
        );
        r.stat(&format!("fault/S4/{label}/p99-ms"), snap.latency_p99_ms, "ms");
        r.stat(&format!("fault/S4/{label}/panicked"), snap.panicked as f64, "req");
        r.stat(&format!("fault/S4/{label}/restarts"), snap.restarts as f64, "respawn");
        cluster.shutdown();
    }

    r.save("bench_coordinator");
}
