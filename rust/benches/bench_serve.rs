//! Serving-path throughput and latency: the fused `serve::Scorer`
//! against the layered `transform_codes → predict_on` baseline it
//! replaced, plus the zero-allocation claim checked with a counting
//! global allocator (every heap alloc in this binary bumps a counter,
//! so "0 allocs/row" is measured, not asserted from reading the code).
//!
//! Rows:
//! * `codes-baseline/*` — the pre-fusion batch path (CodeMatrix
//!   materialization + per-row predict_on);
//! * `fused-batch/*` — `Scorer::predict_batch` (chunk-parallel);
//! * `fused-batch-T1/*` — the same pinned to one thread;
//! * `fused-single-row/*` — `Scorer::predict_dense` with a reused
//!   scratch (the p50-latency serving entry);
//! * `fused-single-row-allocs-per-row` — steady-state heap allocations
//!   per single-row predict (must be 0; recorded as a stat);
//! * `fused-simd/*` — the runtime-dispatched SIMD gather at one thread
//!   (the `simd-wide` stat records whether wide lanes engaged; set
//!   `MINMAX_SIMD=off` before launch to bench the scalar fallback —
//!   dispatch is latched process-wide on first use);
//! * `fused-f32/*`, `fused-int8/*` — the quantized weight slabs;
//! * `fused-packed/*` — b-bit codes packed into u64 words (emitted only
//!   for word-aligned widths; b=6 cannot pack).
//!
//! Run: `cargo bench --bench bench_serve [-- --quick]`; CI uploads
//! `results/bench/bench_serve.json` as BENCH_serve.json.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use minmax::bench::{black_box, Runner};
use minmax::data::synth::{generate, SynthConfig};
use minmax::pipeline::Pipeline;
use minmax::serve::SlabPrecision;
use minmax::util::{pool, simd};

/// System allocator wrapped with an allocation counter.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to the `System` allocator — every
// `GlobalAlloc` contract (layout validity, ptr provenance) is upheld
// by forwarding the caller's arguments unchanged; the counter bump has
// no allocator-visible effect.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to `System.alloc` under the caller's contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout the caller guaranteed valid.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: delegates to `System.dealloc` under the caller's contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come from a matching `alloc` above.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: delegates to `System.realloc` under the caller's contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: arguments forwarded unchanged from the caller.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    let mut r = Runner::new();
    let threads = pool::default_threads();

    // Service-shaped workload: letter analog (D=16) and a wider synth
    // (D=64) at the paper's default k=128, b=8.
    for (name, k, i_bits) in [("letter", 128usize, 8u8), ("usps", 64, 6)] {
        let ds = generate(name, SynthConfig { seed: 3, n_train: 300, n_test: 512 })
            .expect("synth dataset");
        let mut pipe = Pipeline::builder()
            .seed(5)
            .samples(k)
            .i_bits(i_bits)
            .build()
            .expect("build pipeline");
        pipe.fit(&ds.train_x, &ds.train_y).expect("fit");
        let scorer = pipe.scorer(ds.dim()).expect("scorer");
        let n = ds.test_x.rows();
        let tag = format!("{name}/D{}/k{k}/b{i_bits}", ds.dim());
        let thr = Some((n as f64, "row"));

        // Parity guard before any timing: a bench that measures a path
        // with different answers is worse than no bench.
        let model = pipe.model().expect("fitted");
        let codes = pipe.transform_codes(&ds.test_x);
        let baseline: Vec<i32> = (0..n).map(|i| model.predict_on(&codes, i)).collect();
        assert_eq!(scorer.predict_batch(&ds.test_x), baseline);

        // The layered baseline the fused path replaced.
        r.bench_with_throughput(&format!("codes-baseline/{tag}"), thr, || {
            let codes = pipe.transform_codes(&ds.test_x);
            let model = pipe.model().unwrap();
            let preds: Vec<i32> =
                (0..codes.rows()).map(|i| model.predict_on(&codes, i)).collect();
            black_box(preds);
        });

        r.bench_with_throughput(&format!("fused-batch-T{threads}/{tag}"), thr, || {
            black_box(scorer.predict_batch(&ds.test_x));
        });
        r.bench_with_throughput(&format!("fused-batch-T1/{tag}"), thr, || {
            black_box(scorer.predict_batch_with_threads(&ds.test_x, 1));
        });

        // Single-row low-latency entry with a reused scratch.
        let dense = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut i = 0usize;
        r.bench_with_throughput(&format!("fused-single-row/{tag}"), Some((1.0, "row")), || {
            black_box(scorer.predict_dense(dense.row(i % dense.rows()), &mut scratch));
            i += 1;
        });

        // Zero-allocation claim, measured: warm the scratch (buffers
        // grow to their steady-state capacity), then count every heap
        // allocation across M single-row predicts.
        for w in 0..dense.rows() {
            black_box(scorer.predict_dense(dense.row(w), &mut scratch));
        }
        let m = 2000usize;
        let before = ALLOCS.load(Ordering::Relaxed);
        for j in 0..m {
            black_box(scorer.predict_dense(dense.row(j % dense.rows()), &mut scratch));
        }
        let delta = ALLOCS.load(Ordering::Relaxed) - before;
        r.stat(
            &format!("fused-single-row-allocs-per-row/{tag}"),
            delta as f64 / m as f64,
            "alloc/row",
        );
        assert_eq!(delta, 0, "steady-state single-row scoring must not allocate ({tag})");

        // The PR 7 variants. `fused-simd` is the dispatched gather at
        // one thread (compare against `fused-batch-T1` across a
        // MINMAX_SIMD=off run to isolate the lanes); the rest swap the
        // slab precision or the code representation.
        r.stat(&format!("simd-wide/{tag}"), if simd::wide() { 1.0 } else { 0.0 }, "bool");
        r.bench_with_throughput(&format!("fused-simd/{tag}"), thr, || {
            black_box(scorer.predict_batch_with_threads(&ds.test_x, 1));
        });

        let agreement = |labels: &[i32]| {
            labels.iter().zip(&baseline).filter(|(a, b)| a == b).count() as f64 / n as f64
        };
        let f32_scorer = scorer.clone().with_precision(SlabPrecision::F32);
        let f32_labels = f32_scorer.predict_batch_with_threads(&ds.test_x, 1);
        assert!(agreement(&f32_labels) >= 0.98, "f32 slab drifted from the f64 baseline ({tag})");
        r.bench_with_throughput(&format!("fused-f32/{tag}"), thr, || {
            black_box(f32_scorer.predict_batch_with_threads(&ds.test_x, 1));
        });

        let int8_scorer = scorer.clone().with_precision(SlabPrecision::Int8);
        assert_eq!(int8_scorer.precision(), SlabPrecision::Int8, "int8 gate must engage ({tag})");
        let int8_labels = int8_scorer.predict_batch_with_threads(&ds.test_x, 1);
        let int8_agree = agreement(&int8_labels);
        r.stat(&format!("fused-int8-agreement/{tag}"), int8_agree, "frac");
        assert!(int8_agree >= 0.90, "int8 slab failed the accuracy floor ({tag})");
        r.bench_with_throughput(&format!("fused-int8/{tag}"), thr, || {
            black_box(int8_scorer.predict_batch_with_threads(&ds.test_x, 1));
        });

        let packed_scorer = scorer.clone().with_packed_codes(true);
        if packed_scorer.packed_codes() {
            // Packing never changes bits, so the guard is exact; and the
            // packed single-row path must stay allocation-free too.
            assert_eq!(packed_scorer.predict_batch_with_threads(&ds.test_x, 1), baseline);
            r.bench_with_throughput(&format!("fused-packed/{tag}"), thr, || {
                black_box(packed_scorer.predict_batch_with_threads(&ds.test_x, 1));
            });
            let mut pscratch = packed_scorer.scratch();
            for w in 0..dense.rows() {
                black_box(packed_scorer.predict_dense(dense.row(w), &mut pscratch));
            }
            let before = ALLOCS.load(Ordering::Relaxed);
            for j in 0..m {
                black_box(packed_scorer.predict_dense(dense.row(j % dense.rows()), &mut pscratch));
            }
            let delta = ALLOCS.load(Ordering::Relaxed) - before;
            assert_eq!(delta, 0, "packed single-row scoring must not allocate ({tag})");
        }
    }

    r.save("bench_serve");
}
