//! Sub-linear retrieval at scale: the banded b-bit `PackedLshIndex`
//! against an exact brute-force min-max scan over a **million-row**
//! corpus, plus the zero-allocation claims for both query paths checked
//! with a counting global allocator (same methodology as `bench_serve`:
//! "0 allocs/query" is measured, not asserted from reading the code).
//!
//! Rows / stats:
//! * `lsh-build-rows-per-s` — one-shot index build rate (parallel
//!   engine sketch → packed slab → band tables);
//! * `brute-force/1M` — exact top-10 by scanning all rows per query
//!   (the ground-truth baseline the speedup is measured against);
//! * `lsh-query/1M/p{N}` — top-10 through the index at N extra probes
//!   (scratch reuse — the steady-state serving rate);
//! * `lsh-recall-at-10/p{N}`, `lsh-candidates-per-query/p{N}` — quality
//!   and the sub-linear part: how little of the corpus each query
//!   touches before exact re-ranking;
//! * `lsh-speedup-vs-brute` — qps ratio at the cheapest probe setting
//!   reaching recall@10 ≥ 0.9 (asserted ≥ 10×);
//! * `*-allocs-per-query` — steady-state heap allocations per call for
//!   the packed query path and the legacy `LshIndex` candidates/query
//!   paths (all must be 0).
//!
//! Run: `cargo bench --bench bench_lsh [-- --quick]`; CI uploads
//! `results/bench/bench_lsh.json` as BENCH_lsh.json. The corpus stays
//! at 1M rows even under `--quick` — the headline claim is about scale.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use minmax::bench::{black_box, Runner};
use minmax::cws::{LshConfig, LshIndex, PackedLshIndex, QueryParams, QueryScratch};
use minmax::data::sparse::{Csr, CsrBuilder};
use minmax::kernels::sparse_minmax;
use minmax::util::rng::Pcg64;

/// System allocator wrapped with an allocation counter.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to the `System` allocator — every
// `GlobalAlloc` contract (layout validity, ptr provenance) is upheld
// by forwarding the caller's arguments unchanged; the counter bump has
// no allocator-visible effect.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to `System.alloc` under the caller's contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: same layout the caller guaranteed valid.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: delegates to `System.dealloc` under the caller's contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come from a matching `alloc` above.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: delegates to `System.realloc` under the caller's contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: arguments forwarded unchanged from the caller.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const VOCAB: usize = 30_000;
const NNZ: usize = 12;
const GROUP: usize = 10;
const TOP: usize = 10;

fn prototype(rng: &mut Pcg64) -> Vec<(u32, f32)> {
    let mut ids = rng.sample_indices(VOCAB, NNZ);
    ids.sort_unstable();
    ids.into_iter().map(|i| (i as u32, rng.lognormal(0.0, 1.0) as f32)).collect()
}

fn jitter(proto: &[(u32, f32)], rng: &mut Pcg64) -> Vec<(u32, f32)> {
    proto
        .iter()
        .map(|&(w, v)| {
            if rng.uniform() < 0.03 {
                (rng.below(VOCAB as u64) as u32, v)
            } else {
                (w, (v as f64 * rng.lognormal(0.0, 0.08)) as f32)
            }
        })
        .collect()
}

/// `rows` rows in groups of `GROUP` near-duplicates; returns the corpus
/// and the first `keep` group prototypes (held-out query sources).
fn build_corpus(rows: usize, keep: usize, seed: u64) -> (Csr, Vec<Vec<(u32, f32)>>) {
    let mut rng = Pcg64::new(seed);
    let mut b = CsrBuilder::new(VOCAB);
    let mut protos = Vec::with_capacity(keep);
    let mut pushed = 0usize;
    while pushed < rows {
        let p = prototype(&mut rng);
        for _ in 0..GROUP.min(rows - pushed) {
            b.push_row(jitter(&p, &mut rng));
            pushed += 1;
        }
        if protos.len() < keep {
            protos.push(p);
        }
    }
    (b.finish(), protos)
}

fn main() {
    let mut r = Runner::new();
    let rows = 1_000_000usize;
    let n_queries = 64usize;

    let (corpus, protos) = build_corpus(rows, n_queries, 20150704);
    let corpus = Arc::new(corpus);
    let mut rng = Pcg64::new(7);
    let queries: Vec<(Vec<u32>, Vec<f32>)> = protos
        .iter()
        .map(|p| {
            let mut qb = CsrBuilder::new(VOCAB);
            qb.push_row(jitter(p, &mut rng));
            let q = qb.finish();
            (q.row(0).indices.to_vec(), q.row(0).values.to_vec())
        })
        .collect();
    let query = |i: usize| minmax::data::SparseRow {
        indices: &queries[i].0,
        values: &queries[i].1,
    };

    // Build: one shot, timed by hand (repeating a ~1M-row build inside
    // the sampling loop would dominate the bench budget).
    let cfg = LshConfig { bands: 16, rows_per_band: 3, seed: 5 };
    let bits = 8u8;
    let t0 = Instant::now();
    let index = PackedLshIndex::build(Arc::clone(&corpus), cfg, bits).expect("valid config");
    let build_s = t0.elapsed().as_secs_f64();
    r.stat("lsh-build-rows-per-s", rows as f64 / build_s, "row/s");
    r.stat("lsh-mean-bucket-size", index.mean_bucket_size(), "row");

    // Exact ground truth (and the brute-force qps baseline).
    let brute_topk = |q: minmax::data::SparseRow<'_>| -> Vec<(u32, f64)> {
        let mut scored: Vec<(u32, f64)> =
            (0..rows).map(|i| (i as u32, sparse_minmax(q, corpus.row(i)))).collect();
        scored.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        scored.truncate(TOP);
        scored
    };
    let truth: Vec<Vec<(u32, f64)>> = (0..n_queries).map(|i| brute_topk(query(i))).collect();

    let mut bi = 0usize;
    r.bench_with_throughput("brute-force/1M", Some((1.0, "query")), || {
        black_box(brute_topk(query(bi % n_queries)));
        bi += 1;
    });

    // LSH query path at increasing probe budgets.
    let mut s = QueryScratch::new();
    let probe_grid = [0usize, 2, 8];
    let mut recalls = Vec::new();
    for &probes in &probe_grid {
        let params = QueryParams { probes, min_agreement: 0.0 };
        let mut hits = 0usize;
        let mut cands = 0usize;
        for i in 0..n_queries {
            cands += index.candidates_with(query(i), params, &mut s).len();
            let got = index.query_with(query(i), TOP, params, &mut s);
            hits += truth[i].iter().filter(|(id, _)| got.iter().any(|&(g, _)| g == *id)).count();
        }
        let recall = hits as f64 / (n_queries * TOP) as f64;
        recalls.push(recall);
        r.stat(&format!("lsh-recall-at-10/p{probes}"), recall, "frac");
        r.stat(
            &format!("lsh-candidates-per-query/p{probes}"),
            cands as f64 / n_queries as f64,
            "row",
        );
        let mut qi = 0usize;
        r.bench_with_throughput(&format!("lsh-query/1M/p{probes}"), Some((1.0, "query")), || {
            black_box(index.query_with(query(qi % n_queries), TOP, params, &mut s));
            qi += 1;
        });
    }

    // Zero-allocation claims, measured. Packed path first: warm, then
    // count every heap allocation across M steady-state queries.
    let params = QueryParams { probes: 2, min_agreement: 0.5 };
    for i in 0..n_queries {
        black_box(index.query_with(query(i), TOP, params, &mut s));
        black_box(index.candidates_with(query(i), params, &mut s));
    }
    let m = 2000usize;
    let before = ALLOCS.load(Ordering::Relaxed);
    for j in 0..m {
        black_box(index.query_with(query(j % n_queries), TOP, params, &mut s));
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    r.stat("lsh-query-allocs-per-query", delta as f64 / m as f64, "alloc/query");
    assert_eq!(delta, 0, "steady-state packed query must not allocate");

    // Legacy index (FNV-keyed buckets) on a sub-corpus: the zero-alloc
    // contract for the pre-existing API, now routed through the same
    // QueryScratch.
    let small = Arc::new(corpus.select_rows(&(0..20_000usize).collect::<Vec<_>>()));
    let legacy = LshIndex::try_build(Arc::clone(&small), cfg).expect("valid config");
    for i in 0..n_queries {
        black_box(legacy.candidates_with(query(i), &mut s));
        black_box(legacy.query_with(query(i), TOP, &mut s));
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for j in 0..m {
        black_box(legacy.candidates_with(query(j % n_queries), &mut s));
        black_box(legacy.query_with(query(j % n_queries), TOP, &mut s));
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    r.stat("legacy-query-allocs-per-query", delta as f64 / (2 * m) as f64, "alloc/query");
    assert_eq!(delta, 0, "steady-state legacy candidates/query must not allocate");

    // Headline: qps ratio at the cheapest probe setting that clears the
    // recall floor.
    let median = |name: &str| -> f64 {
        r.results()
            .iter()
            .find(|m| m.name == name)
            .map(|m| m.median)
            .unwrap_or_else(|| panic!("missing measurement {name}"))
    };
    let brute_qps = 1.0 / median("brute-force/1M");
    let (mut speedup, mut chosen) = (0.0f64, None);
    for (i, &probes) in probe_grid.iter().enumerate() {
        if recalls[i] >= 0.9 {
            speedup = (1.0 / median(&format!("lsh-query/1M/p{probes}"))) / brute_qps;
            chosen = Some(probes);
            break;
        }
    }
    let chosen = chosen.expect("no probe setting reached recall@10 >= 0.9 on 1M rows");
    r.stat("lsh-speedup-vs-brute", speedup, "x");
    assert!(
        speedup >= 10.0,
        "LSH at p{chosen} must be >= 10x brute force (got {speedup:.1}x)"
    );

    r.save("bench_lsh");
}
