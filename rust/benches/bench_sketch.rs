//! Sketching-core throughput: the before/after record for the
//! loop-inverted `SketchEngine` refactor (EXPERIMENTS.md §Perf).
//!
//! Measures rows/sec at varying (nnz, k) for:
//!
//! * `strided-pre` — the PRE-refactor materialized loop, reproduced here
//!   verbatim (outer over samples, strided `[j*dim + i]` reads,
//!   branchy argmin) so the speedup stays measurable after the old code
//!   is gone;
//! * `lazy` — `CwsHasher` per-row hashing (parameters derived on the
//!   fly; the no-materialization baseline);
//! * `engine-T1` — the engine batch entry pinned to one thread (pure
//!   loop-inversion + transposed-slab effect);
//! * `engine-par` — the same entry at `MINMAX_THREADS`/default threads
//!   (the chunked parallel scaling the coordinator and pipeline ride);
//! * `engine-fast-T1` — single-thread engine with the accuracy-checked
//!   `util::fastmath` toggle engaged.
//!
//! Run: `cargo bench --bench bench_sketch [-- --quick]`; CI uploads
//! `results/bench/bench_sketch.json` as the `BENCH_sketch.json`
//! artifact next to `BENCH_pipeline.json`.

use minmax::bench::{black_box, Runner};
use minmax::cws::sampler::params_at;
use minmax::cws::{CwsHasher, CwsSample, SketchEngine};
use minmax::util::pool;
use minmax::util::rng::Pcg64;

/// The pre-refactor `DenseBatchHasher`: `(r, c, β)` laid out
/// `[j*dim + i]`, outer loop over samples, inner over nonzeros, branchy
/// argmin — kept here (and only here) as the measurable "before".
struct StridedReference {
    k: usize,
    dim: usize,
    r: Vec<f64>,
    c: Vec<f64>,
    beta: Vec<f64>,
}

impl StridedReference {
    fn new(seed: u64, k: usize, dim: usize) -> Self {
        let n = k * dim;
        let (mut r, mut c, mut beta) =
            (Vec::with_capacity(n), Vec::with_capacity(n), Vec::with_capacity(n));
        for j in 0..k as u32 {
            for i in 0..dim as u32 {
                let (rr, cc, bb) = params_at(seed, j, i);
                r.push(rr);
                c.push(cc);
                beta.push(bb);
            }
        }
        Self { k, dim, r, c, beta }
    }

    fn hash(&self, u: &[f32]) -> Vec<CwsSample> {
        let mut indices: Vec<u32> = Vec::with_capacity(u.len());
        let mut ln_u: Vec<f64> = Vec::with_capacity(u.len());
        for (i, &ui) in u.iter().enumerate() {
            if ui > 0.0 {
                indices.push(i as u32);
                ln_u.push((ui as f64).ln());
            }
        }
        let mut out = Vec::with_capacity(self.k);
        for j in 0..self.k {
            let base = j * self.dim;
            let mut best_a = f64::INFINITY;
            let mut best = CwsSample { i_star: u32::MAX, t_star: 0 };
            for (&i, &lnu) in indices.iter().zip(&ln_u) {
                let idx = base + i as usize;
                let (r, c, beta) = (self.r[idx], self.c[idx], self.beta[idx]);
                let t = (lnu / r + beta).floor();
                let a = c * (-(r * (t - beta)) - r).exp();
                if a < best_a {
                    best_a = a;
                    best = CwsSample { i_star: i, t_star: t as i64 };
                }
            }
            out.push(best);
        }
        out
    }
}

fn random_rows(n: usize, dim: usize, zero_frac: f64, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let mut v: Vec<f32> = (0..dim)
                .map(|_| {
                    if rng.uniform() < zero_frac {
                        0.0
                    } else {
                        rng.lognormal(0.0, 1.0) as f32
                    }
                })
                .collect();
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            v
        })
        .collect()
}

fn main() {
    let mut r = Runner::new();
    let n_rows = 64usize;
    let threads = pool::default_threads();

    // (dim, k, zero_frac): dense small, dense service-shaped, sparse
    // service-shaped, large sparse.
    for (dim, k, zf) in
        [(64usize, 64usize, 0.0), (256, 128, 0.0), (256, 128, 0.9), (1024, 256, 0.95)]
    {
        let rows = random_rows(n_rows, dim, zf, 1);
        let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
        let nnz = refs[0].iter().filter(|&&v| v > 0.0).count();
        let tag = format!("D{dim}/k{k}/nnz{nnz}");
        let thr = Some((n_rows as f64, "row"));

        let strided = StridedReference::new(7, k, dim);
        let lazy = CwsHasher::new(7, k);
        // Exact mode pinned: the engine-T1/engine-par rows must measure
        // the bit-identical path even if MINMAX_FAST_MATH is set.
        let engine = SketchEngine::new(7, k, dim).with_fast_math(false);
        // Parity guard BEFORE any timing: a bench that measures the
        // wrong bits is worse than no bench, and nothing should be
        // recorded for this commit if the paths diverge.
        assert_eq!(engine.sketch_dense(&rows[0]), strided.hash(&rows[0]));
        assert_eq!(engine.sketch_dense(&rows[0]), lazy.hash_dense(&rows[0]));

        r.bench_with_throughput(&format!("strided-pre/{tag}"), thr, || {
            for row in &refs {
                black_box(strided.hash(row));
            }
        });

        r.bench_with_throughput(&format!("lazy/{tag}"), thr, || {
            for row in &refs {
                black_box(lazy.hash_dense(row));
            }
        });

        r.bench_with_throughput(&format!("engine-T1/{tag}"), thr, || {
            black_box(engine.sketch_rows_with_threads(&refs, 1));
        });
        r.bench_with_throughput(&format!("engine-par-T{threads}/{tag}"), thr, || {
            black_box(engine.sketch_rows(&refs));
        });

        let fast = SketchEngine::new(7, k, dim).with_fast_math(true);
        r.bench_with_throughput(&format!("engine-fast-T1/{tag}"), thr, || {
            black_box(fast.sketch_rows_with_threads(&refs, 1));
        });
    }

    r.save("bench_sketch");
}
