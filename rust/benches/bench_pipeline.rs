//! End-to-end coordinator benchmarks: the online hashing service (native
//! and PJRT backends) and the fused PJRT serving path. The numbers here
//! are the paper's "industrial applications" story quantified, and the
//! before/after log in EXPERIMENTS.md §Perf is measured with this
//! binary.
//!
//! Run: `make artifacts && cargo bench --bench bench_pipeline [-- --quick]`

use std::time::Duration;

use minmax::bench::{black_box, Runner};
use minmax::coordinator::{HashService, NativeBackend, PjrtBackend, ServiceConfig};
use minmax::runtime::default_artifacts_dir;
use minmax::util::rng::Pcg64;

fn random_vec(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    (0..dim).map(|_| rng.lognormal(0.0, 1.0) as f32).collect()
}

fn main() {
    let mut r = Runner::new();
    let dim = 256;
    let k = 128;

    // Native service, closed loop, single submitter.
    let svc = HashService::start(
        ServiceConfig {
            seed: 1,
            k,
            dim,
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 4096,
        },
        NativeBackend,
    )
    .expect("start native service");
    let v = random_vec(dim, 2);
    let mut id = 0u64;
    r.bench_with_throughput("service-native/hash_blocking/D256k128", Some((1.0, "req")), || {
        id += 1;
        black_box(svc.hash_blocking(id, &v).unwrap());
    });
    // Burst submission (exercises the dynamic batcher).
    r.bench_with_throughput("service-native/burst32/D256k128", Some((32.0, "req")), || {
        let rxs: Vec<_> = (0..32)
            .map(|i| loop {
                match svc.submit(i, v.clone()) {
                    Ok(rx) => break rx,
                    Err(_) => std::thread::yield_now(),
                }
            })
            .collect();
        for rx in rxs {
            black_box(rx.recv().unwrap().unwrap());
        }
    });
    svc.shutdown();

    // Offline batch path: Pipeline::transform over a whole matrix. This
    // rides the SketchEngine chunked-parallel batch entry via the
    // Sketcher overrides (MINMAX_THREADS controls sharding), so this
    // number plus bench_sketch's engine rows/sec are the before/after
    // record for the loop-inversion refactor (EXPERIMENTS.md §Perf).
    {
        use minmax::data::synth::{generate, SynthConfig};
        use minmax::pipeline::Pipeline;
        let ds = generate("letter", SynthConfig { seed: 3, n_train: 512, n_test: 1 })
            .expect("synth dataset");
        let pipe =
            Pipeline::builder().seed(5).samples(128).i_bits(8).build().expect("build pipeline");
        r.bench_with_throughput("pipeline-transform/letter512/k128", Some((512.0, "row")), || {
            black_box(pipe.transform(&ds.train_x));
        });
    }

    // PJRT-backed service (skipped without artifacts).
    let dir = default_artifacts_dir();
    if minmax::runtime::pjrt_enabled() && dir.join("manifest.json").exists() {
        let svc = HashService::start(
            ServiceConfig {
                seed: 1,
                k,
                dim,
                max_batch: 64,
                max_wait: Duration::from_micros(200),
                queue_cap: 4096,
            },
            PjrtBackend::new(dir.clone(), "cws_hash"),
        )
        .expect("start pjrt service");
        r.bench_with_throughput("service-pjrt/burst64/D256k128", Some((64.0, "req")), || {
            let rxs: Vec<_> = (0..64)
                .map(|i| loop {
                    match svc.submit(i, v.clone()) {
                        Ok(rx) => break rx,
                        Err(_) => std::thread::yield_now(),
                    }
                })
                .collect();
            for rx in rxs {
                black_box(rx.recv().unwrap().unwrap());
            }
        });
        svc.shutdown();

        // Raw PJRT execute (no service overhead) for overhead accounting.
        use minmax::cws::materialize_params;
        use minmax::runtime::{literal_f32, Engine};
        let engine = Engine::load_subset(&dir, &["cws_hash"]).unwrap();
        let spec = engine.spec("cws_hash").unwrap().clone();
        let (b, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        let kk = spec.inputs[1].shape[0];
        let (rr, cc, bb) = materialize_params(1, d, kk);
        let xl = literal_f32(&random_vec(b * d, 5), &[b, d]).unwrap();
        let rl = literal_f32(&rr, &[kk, d]).unwrap();
        let cl = literal_f32(&cc, &[kk, d]).unwrap();
        let bl = literal_f32(&bb, &[kk, d]).unwrap();
        r.bench_with_throughput(
            &format!("pjrt-raw/cws_hash/B{b}D{d}K{kk}"),
            Some((b as f64, "vec")),
            || {
                black_box(
                    engine
                        .run("cws_hash", &[xl.clone(), rl.clone(), cl.clone(), bl.clone()])
                        .unwrap(),
                );
            },
        );
    } else {
        eprintln!("skipping PJRT benches: build with --features pjrt and run `make artifacts`");
    }

    r.save("bench_pipeline");
}
