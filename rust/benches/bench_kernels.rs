//! Kernel-evaluation benchmarks — the compute behind Table 1 /
//! Figures 1–3 (kernel matrices) and the §Perf L3 roofline analysis.
//!
//! Run: `cargo bench --bench bench_kernels [-- --filter minmax --quick]`

use minmax::bench::{black_box, Runner};
use minmax::data::dense::Dense;
use minmax::data::sparse::Csr;
use minmax::data::Matrix;
use minmax::kernels::gram::{GramSource, OnTheFly};
use minmax::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
use minmax::kernels::KernelKind;
use minmax::svm::kernel::{train_binary, train_binary_on};
use minmax::svm::KernelSvmParams;
use minmax::util::rng::Pcg64;

fn random_dense(rows: usize, cols: usize, zero_frac: f64, seed: u64) -> Dense {
    let mut rng = Pcg64::new(seed);
    Dense::from_vec(
        rows,
        cols,
        (0..rows * cols)
            .map(|_| {
                if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.lognormal(0.0, 1.0) as f32
                }
            })
            .collect(),
    )
}

fn main() {
    let mut r = Runner::new();

    // Pairwise kernel evaluation (per-element costs).
    let a = random_dense(1, 1024, 0.0, 1);
    let b = random_dense(1, 1024, 0.0, 2);
    for kern in [KernelKind::Linear, KernelKind::MinMax, KernelKind::Intersection, KernelKind::Chi2] {
        r.bench_with_throughput(
            &format!("pairwise/{}/d1024", kern.name()),
            Some((1024.0, "elem")),
            || {
                black_box(kern.eval_dense(a.row(0), b.row(0)));
            },
        );
    }

    // Sparse merge-join path at 10% density.
    let sa = Csr::from_dense(&random_dense(1, 4096, 0.9, 3));
    let sb = Csr::from_dense(&random_dense(1, 4096, 0.9, 4));
    for kern in [KernelKind::Linear, KernelKind::MinMax, KernelKind::Resemblance] {
        r.bench_with_throughput(
            &format!("pairwise-sparse/{}/d4096@10%", kern.name()),
            Some(((sa.nnz() + sb.nnz()) as f64, "nnz")),
            || {
                black_box(kern.eval_sparse(sa.row(0), sb.row(0)));
            },
        );
    }

    // Kernel-matrix blocks (the Table-1 hot path).
    let x = random_dense(128, 64, 0.0, 5);
    let y = random_dense(128, 64, 0.0, 6);
    let mx = Matrix::Dense(x);
    let my = Matrix::Dense(y);
    for kern in [KernelKind::Linear, KernelKind::MinMax] {
        r.bench_with_throughput(
            &format!("matrix/{}/128x128xD64", kern.name()),
            Some(((128 * 128) as f64, "pair")),
            || {
                black_box(kernel_matrix(kern, &mx, &my));
            },
        );
    }

    // Symmetric (training) Gram: upper triangle + mirror.
    r.bench_with_throughput(
        "matrix-sym/min-max/128x128xD64",
        Some(((128 * 129 / 2) as f64, "pair")),
        || {
            black_box(kernel_matrix_sym(KernelKind::MinMax, &mx));
        },
    );

    // Gram sources: kernel-SVM training cost per path, in solver-visible
    // rows/s, plus the rows-materialized peak-memory proxy. `pre` pays
    // the full n×n matrix up front; `otf-cold` streams rows through a
    // 25%-of-n LRU cache from scratch every call; `otf-hot` reuses a
    // persistent full-size cache (misses only on the first call).
    let n = 192usize;
    let xg = Matrix::Dense(random_dense(n, 48, 0.3, 7));
    let yg: Vec<i32> = (0..n).map(|i| if i % 2 == 0 { 1 } else { -1 }).collect();
    let p = KernelSvmParams { c: 4.0, max_epochs: 40, ..Default::default() };
    r.bench_with_throughput(&format!("gram/pre/train-{n}"), Some((n as f64, "row")), || {
        let k = kernel_matrix_sym(KernelKind::MinMax, &xg);
        black_box(train_binary(&k, &yg, &p));
    });
    r.bench_with_throughput(&format!("gram/otf-cold/train-{n}"), Some((n as f64, "row")), || {
        let src = OnTheFly::new(KernelKind::MinMax, &xg).with_cache_rows(n / 4);
        black_box(train_binary_on(&src, &yg, &p));
    });
    let hot = OnTheFly::new(KernelKind::MinMax, &xg).with_cache_rows(n);
    black_box(train_binary_on(&hot, &yg, &p)); // warm the cache once
    r.bench_with_throughput(&format!("gram/otf-hot/train-{n}"), Some((n as f64, "row")), || {
        black_box(train_binary_on(&hot, &yg, &p));
    });
    // Memory proxies: rows materialized by one training run per path
    // (pre always holds all n; otf is bounded by its cache and counts
    // recomputation work).
    let cold = OnTheFly::new(KernelKind::MinMax, &xg).with_cache_rows(n / 4);
    black_box(train_binary_on(&cold, &yg, &p));
    r.stat(&format!("gram/pre/rows-materialized-{n}"), n as f64, "row");
    r.stat(
        &format!("gram/otf-cold/rows-materialized-{n}"),
        cold.rows_materialized() as f64,
        "row",
    );
    r.stat(&format!("gram/otf-cold/rows-resident-{n}"), cold.cached_rows() as f64, "row");
    r.stat(&format!("gram/otf-hot/rows-materialized-{n}"), hot.rows_materialized() as f64, "row");

    r.save("bench_kernels");
}
