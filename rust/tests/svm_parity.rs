//! Code-path / CSR-path parity for the learning layer.
//!
//! The one-hot `CodeMatrix` fast path must be a pure representation
//! change: training over the codes and over the equivalent CSR (same
//! seed, same coordinate order) must produce **bit-identical** models
//! and decisions — `svm::rowset` keeps the two `dot` reduction trees in
//! lockstep and `w[j]·1.0 = w[j]` exactly, so any drift here is a bug,
//! not noise. Parallel OvR/OvO must likewise be a pure throughput knob:
//! explicit 1-thread and 4-thread training (and whatever
//! `MINMAX_THREADS` CI pins — the suite runs under both `=1` and `=4`)
//! produce identical models.

use minmax::coordinator::{hash_dataset, hash_matrix_native, PipelineConfig};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Dataset;
use minmax::kernels::matrix::kernel_matrix_sym;
use minmax::kernels::KernelKind;
use minmax::svm::linear::train_binary;
use minmax::svm::{
    logistic, KernelOvO, KernelSvmParams, LinearOvR, LinearSvmParams, LogisticParams, Loss,
};

fn hashed_letter() -> (Dataset, minmax::coordinator::HashedDataset) {
    let ds = generate("letter", SynthConfig { seed: 13, n_train: 150, n_test: 100 }).unwrap();
    let hashed = hash_dataset(&ds, &PipelineConfig::new(5, 64, 6)).unwrap();
    (ds, hashed)
}

fn binary_labels(y: &[i32]) -> Vec<i32> {
    y.iter().map(|&c| if c % 2 == 0 { 1 } else { -1 }).collect()
}

#[test]
fn code_matrix_is_the_expansion_exactly() {
    let (ds, hashed) = hashed_letter();
    hashed.train.check_invariants().unwrap();
    let samples = hash_matrix_native(&ds.train_x, 5, 64);
    assert_eq!(hashed.train_csr(), hashed.expansion.expand(&samples));
    assert_eq!(hashed.train.nnz(), hashed.train_csr().nnz());
}

#[test]
fn linear_svm_trains_bit_identically_on_codes_and_csr() {
    let (ds, hashed) = hashed_letter();
    let y = binary_labels(&ds.train_y);
    let (train_csr, test_csr) = (hashed.train_csr(), hashed.test_csr());
    for loss in [Loss::L1, Loss::L2] {
        let p = LinearSvmParams { loss, c: 1.0, ..Default::default() };
        let mc = train_binary(&hashed.train, &y, &p);
        let ms = train_binary(&train_csr, &y, &p);
        assert_eq!(mc.epochs_run, ms.epochs_run, "{loss:?}");
        assert_eq!(mc.b.to_bits(), ms.b.to_bits(), "{loss:?}");
        assert!(
            mc.w.iter().zip(&ms.w).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{loss:?}: weight vectors must be bit-identical"
        );
        for i in 0..hashed.test.rows() {
            assert_eq!(
                mc.decision_on(&hashed.test, i).to_bits(),
                ms.decision_on(&test_csr, i).to_bits(),
                "{loss:?} row {i}"
            );
        }
    }
}

#[test]
fn logistic_trains_bit_identically_on_codes_and_csr() {
    let (ds, hashed) = hashed_letter();
    let y = binary_labels(&ds.train_y);
    let p = LogisticParams { max_iters: 25, ..Default::default() };
    let mc = logistic::train_binary(&hashed.train, &y, &p);
    let ms = logistic::train_binary(&hashed.train_csr(), &y, &p);
    assert_eq!(mc.iters_run, ms.iters_run);
    assert_eq!(mc.b.to_bits(), ms.b.to_bits());
    assert!(mc.w.iter().zip(&ms.w).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn empty_rows_are_parity_preserving() {
    // Hand-built batch with empty rows in the middle: the mask path of
    // CodeMatrix must behave exactly like the empty CSR rows.
    use minmax::prelude::{CwsHasher, Expansion};
    let e = Expansion::new(16, 4);
    let h = CwsHasher::new(3, 16);
    let samples = vec![
        Some(h.hash_dense(&[1.0, 2.0, 0.5])),
        None,
        Some(h.hash_dense(&[0.1, 0.0, 4.0])),
        None,
        Some(h.hash_dense(&[2.0, 2.0, 2.0])),
        Some(h.hash_dense(&[0.0, 0.7, 0.0])),
    ];
    let cm = e.encode(&samples);
    let csr = e.expand(&samples);
    assert_eq!(cm.to_csr(), csr);
    let y = vec![1, -1, 1, -1, 1, -1];
    let p = LinearSvmParams::default();
    let mc = train_binary(&cm, &y, &p);
    let ms = train_binary(&csr, &y, &p);
    assert_eq!(mc.b.to_bits(), ms.b.to_bits());
    assert!(mc.w.iter().zip(&ms.w).all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn parallel_ovr_is_thread_count_invariant() {
    let (ds, hashed) = hashed_letter();
    let n_classes = ds.n_classes();
    let p = LinearSvmParams::default();
    let m1 = LinearOvR::train_with_threads(&hashed.train, &ds.train_y, n_classes, &p, 1);
    let m4 = LinearOvR::train_with_threads(&hashed.train, &ds.train_y, n_classes, &p, 4);
    // The env-driven entry (whatever MINMAX_THREADS CI pins) agrees too.
    let menv = LinearOvR::train(&hashed.train, &ds.train_y, n_classes, &p);
    for (a, b) in m1.models().iter().zip(m4.models()) {
        assert_eq!(a.b.to_bits(), b.b.to_bits());
        assert!(a.w.iter().zip(&b.w).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
    for (a, b) in m1.models().iter().zip(menv.models()) {
        assert_eq!(a.b.to_bits(), b.b.to_bits());
        assert!(a.w.iter().zip(&b.w).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
    for i in 0..hashed.test.rows() {
        assert_eq!(m1.predict_on(&hashed.test, i), m4.predict_on(&hashed.test, i));
    }
}

#[test]
fn ovr_predictions_identical_across_representations() {
    // The acceptance pin: OvR trained on codes vs on the CSR export
    // predicts bit-identically (training AND scoring).
    let (ds, hashed) = hashed_letter();
    let n_classes = ds.n_classes();
    let p = LinearSvmParams::default();
    let (train_csr, test_csr) = (hashed.train_csr(), hashed.test_csr());
    let mc = LinearOvR::train(&hashed.train, &ds.train_y, n_classes, &p);
    let ms = LinearOvR::train(&train_csr, &ds.train_y, n_classes, &p);
    for i in 0..hashed.test.rows() {
        assert_eq!(
            mc.decisions_on(&hashed.test, i)
                .iter()
                .map(|d| d.to_bits())
                .collect::<Vec<_>>(),
            ms.decisions_on(&test_csr, i).iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            "row {i}"
        );
        assert_eq!(mc.predict_on(&hashed.test, i), ms.predict_on(&test_csr, i));
    }
}

#[test]
fn decisions_into_is_a_pure_buffer_variant() {
    // decisions_on is now a thin wrapper over decisions_into; both (and
    // the sparse-row twin) must return the exact bits the per-model
    // decision_on loop returns, into dirty caller buffers.
    let (ds, hashed) = hashed_letter();
    let n_classes = ds.n_classes();
    let model =
        LinearOvR::train(&hashed.train, &ds.train_y, n_classes, &LinearSvmParams::default());
    let test_csr = hashed.test_csr();
    let mut buf = vec![f64::NAN; n_classes]; // dirty on purpose
    for i in 0..hashed.test.rows().min(25) {
        model.decisions_into(&hashed.test, i, &mut buf);
        let want = model.decisions_on(&hashed.test, i);
        assert!(buf.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "row {i}");
        let mut sbuf = vec![f64::INFINITY; n_classes];
        model.decisions_sparse_into(test_csr.row(i), &mut sbuf);
        assert_eq!(sbuf, model.decisions(test_csr.row(i)), "sparse row {i}");
    }
}

#[test]
fn parallel_ovo_is_thread_count_invariant() {
    let ds = generate("vowel", SynthConfig { seed: 7, n_train: 90, n_test: 30 }).unwrap();
    let gram = kernel_matrix_sym(KernelKind::MinMax, &ds.train_x);
    let p = KernelSvmParams::default();
    let m1 = KernelOvO::train_with_threads(&gram, &ds.train_y, ds.n_classes(), &p, 1);
    let m4 = KernelOvO::train_with_threads(&gram, &ds.train_y, ds.n_classes(), &p, 4);
    assert_eq!(m1.n_models(), m4.n_models());
    let test =
        minmax::kernels::matrix::kernel_matrix(KernelKind::MinMax, &ds.test_x, &ds.train_x);
    for i in 0..test.rows() {
        assert_eq!(m1.predict(test.row(i)), m4.predict(test.row(i)), "row {i}");
    }
}
