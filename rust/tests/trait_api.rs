//! Property tests for the trait surface introduced by the API redesign:
//!
//! * **Sketcher parity** — every ICWS-family `Sketcher` impl (lazy
//!   `CwsHasher`, materialized `DenseBatchHasher`) produces identical
//!   samples for the same seed, through trait objects, on random input.
//! * **Kernel ↔ sketcher consistency** — the empirical 0-bit collision
//!   fraction of `Kernel::sketcher(..)`'s samples converges to
//!   `Kernel::eval_dense` within 3σ binomial tolerance (Eq. 7/8 for
//!   min-max, Eq. 2 for resemblance).
//! * **Pipeline consistency** — the `Pipeline` object reproduces the
//!   manual scale→sketch→expand composition exactly.

use minmax::prelude::*;
use minmax::util::prop::{check, ensure, Gen};

fn nonzero_vec(g: &mut Gen, dim: usize, zero_frac: f64) -> Vec<f32> {
    let mut v = g.nonneg_vec(dim, zero_frac);
    if !v.iter().any(|&x| x > 0.0) {
        v[0] = 1.0;
    }
    v
}

#[test]
fn prop_sketcher_impls_agree_for_same_seed() {
    if minmax::cws::engine::fast_math_requested() {
        eprintln!("skipped: bit parity is only claimed without MINMAX_FAST_MATH");
        return;
    }
    check("sketcher-impl-parity", 40, |g| {
        let dim = g.usize_in(1, 80);
        let k = g.usize_in(1, 48);
        let seed = g.rng.next_u64();
        let lazy = CwsHasher::new(seed, k);
        let materialized = lazy.dense_batch(dim);
        // Through trait objects, as the coordinator consumes them.
        let a: &dyn Sketcher = &lazy;
        let b: &dyn Sketcher = &materialized;
        ensure(a.k() == b.k() && a.seed() == b.seed(), "config parity")?;
        for _ in 0..4 {
            let v = nonzero_vec(g, dim, 0.5);
            let sa = a.sketch_dense(&v);
            let sb = b.sketch_dense(&v);
            ensure(sa == sb, "dense samples identical across impls")?;
            let d = Dense::from_rows(&[&v[..]]);
            let s = Csr::from_dense(&d);
            ensure(a.sketch_sparse(s.row(0)) == sa, "lazy sparse == dense")?;
            ensure(b.sketch_sparse(s.row(0)) == sa, "materialized sparse == dense")?;
            let batched = b.sketch_dense_batch(&[&v[..], &v[..]]);
            ensure(batched[0] == sa && batched[1] == sa, "batch hook parity")?;
        }
        Ok(())
    });
}

#[test]
fn prop_zero_bit_collisions_converge_to_kernel_eval() {
    // Kernel::sketcher is the kernel's linearization: collision
    // fraction ≈ Kernel::eval within 3σ (+ the small 0-bit bias bound).
    check("kernel-sketcher-consistency", 12, |g| {
        let dim = g.usize_in(32, 96);
        let u = nonzero_vec(g, dim, 0.3);
        // Correlated partner so the kernel value spreads over (0, 1).
        let v: Vec<f32> = {
            let mut v: Vec<f32> = u
                .iter()
                .map(|&x| {
                    if g.bool_p(0.15) {
                        g.rng.lognormal(0.0, 1.0) as f32
                    } else {
                        (x as f64 * g.rng.lognormal(0.0, 0.4)) as f32
                    }
                })
                .collect();
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            v
        };
        let k = 3000;
        let seed = g.rng.next_u64();
        for kind in [KernelKind::MinMax, KernelKind::Resemblance] {
            let truth = Kernel::eval_dense(&kind, &u, &v);
            let sk = Kernel::sketcher(&kind, seed, k).expect("linearizable kernel");
            let su = sk.sketch_dense(&u);
            let sv = sk.sketch_dense(&v);
            let got = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
            // 3σ binomial tolerance + 0.02 headroom for the 0-bit bias
            // at moderate dimension (§3.4 of the paper).
            let tol = 3.0 * (truth * (1.0 - truth) / k as f64).sqrt() + 0.02;
            ensure(
                (got - truth).abs() <= tol,
                &format!("{}: collisions {got:.4} vs eval {truth:.4} (tol {tol:.4})", kind.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_non_linearizable_kernels_say_so() {
    for kind in [KernelKind::Linear, KernelKind::Intersection, KernelKind::Chi2] {
        assert!(Kernel::sketcher(&kind, 1, 8).is_none(), "{}", kind.name());
    }
    for kind in [KernelKind::MinMax, KernelKind::NMinMax, KernelKind::Resemblance] {
        let s = Kernel::sketcher(&kind, 1, 8).expect("linearizable");
        assert_eq!(s.k(), 8);
        assert_eq!(s.seed(), 1);
    }
}

#[test]
fn prop_pipeline_transform_equals_manual_composition() {
    check("pipeline-equals-manual", 10, |g| {
        let ds = generate("vowel", SynthConfig { seed: g.rng.next_u64(), n_train: 60, n_test: 40 })
            .map_err(|e| e.to_string())?;
        let k = 1 << g.usize_in(3, 6);
        let i_bits = *g.choose(&[2u8, 4, 8]);
        let seed = g.rng.next_u64();
        let pipe = Pipeline::builder()
            .seed(seed)
            .samples(k)
            .i_bits(i_bits)
            .build()
            .map_err(|e| e.to_string())?;
        // Manual composition of the same stages.
        let hasher = CwsHasher::new(seed, k);
        let samples = hasher.sketch_matrix(&ds.train_x);
        let expansion = Expansion::checked(k, i_bits, 0).map_err(|e| e.to_string())?;
        let manual = expansion.expand(&samples);
        ensure(pipe.transform(&ds.train_x) == manual, "pipeline == manual stages")
    });
}

#[test]
fn pipeline_end_to_end_recovers_kernel_accuracy_ordering() {
    // The paper's Figure-7 story through the new API: hashed-linear
    // accuracy grows with k toward the exact min-max kernel SVM.
    let ds = generate("letter", SynthConfig { seed: 11, n_train: 150, n_test: 150 }).unwrap();
    let acc_at = |k: usize| {
        let mut pipe =
            Pipeline::builder().seed(7).samples(k).i_bits(8).cost(1.0).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        pipe.accuracy(&ds.test_x, &ds.test_y).unwrap()
    };
    let small = acc_at(8);
    let large = acc_at(256);
    assert!(large > small + 0.05, "k=8 {small} vs k=256 {large}");
}
