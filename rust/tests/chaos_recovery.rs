//! Seeded chaos harness for the fault-tolerant serving cluster.
//!
//! Drives the production `ScoreRouter`/`QueryRouter` under injected
//! worker panics, worker deaths, slow requests, and queue stalls
//! (`coordinator::faults`, deterministic from one u64 seed) while
//! models hot-swap underneath, and asserts the fault-tolerance
//! contract end to end:
//!
//! * **Exactly one response per accepted request** — never zero (lost)
//!   and never two (duplicate), across panic → respawn → hot-swap.
//! * **Completed predictions are bit-identical** to `Pipeline::predict`
//!   for the model version that scored them — chaos may fail requests,
//!   it may never corrupt one.
//! * **No client blocks past its bound** — every wait here uses
//!   `wait_timeout`; a timeout is a lost response and fails the test.
//! * **The snapshot reconciles**: completed + rejected + shed +
//!   deadline_expired + panicked == requests, with restarts > 0 once
//!   deaths are injected.
//!
//! CI sweeps `MINMAX_FAULT_RATE` ∈ {0, 0.05, 0.2} × `MINMAX_TEST_SHARDS`
//! ∈ {1, 4} (the `chaos` matrix leg); without the env vars this runs
//! rate 0.25 over shard counts {1, 4}.

use std::sync::Arc;
use std::time::Duration;

use minmax::coordinator::{
    silence_injected_panics, ClusterConfig, ClusterError, FaultPlan, QueryRouter, ScoreRouter,
    INJECTED,
};
use minmax::cws::{LshConfig, PackedLshIndex, QueryParams, QueryScratch};
use minmax::data::sparse::{Csr, CsrBuilder};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Dataset;
use minmax::pipeline::Pipeline;
use minmax::util::rng::Pcg64;

/// Headline fault rate: `MINMAX_FAULT_RATE` (the CI chaos matrix) or a
/// hefty default so a bare `cargo test` exercises real chaos.
fn fault_rate() -> f64 {
    std::env::var("MINMAX_FAULT_RATE").ok().and_then(|s| s.trim().parse().ok()).unwrap_or(0.25)
}

fn fault_seed() -> u64 {
    std::env::var("MINMAX_FAULT_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Shard counts under test: `MINMAX_TEST_SHARDS` pins one (the CI
/// matrix), default sweeps both.
fn shard_counts() -> Vec<usize> {
    match std::env::var("MINMAX_TEST_SHARDS") {
        Ok(s) => vec![s.trim().parse().expect("MINMAX_TEST_SHARDS must be a shard count")],
        Err(_) => vec![1, 4],
    }
}

fn chaos_cfg(shards: usize, rate: f64) -> ClusterConfig {
    ClusterConfig {
        shards,
        queue_cap: 1024,
        shed_watermark: None,
        steal: true,
        faults: Some(FaultPlan::with_rate(fault_seed(), rate)),
    }
}

fn letter(data_seed: u64) -> Dataset {
    generate("letter", SynthConfig { seed: data_seed, n_train: 120, n_test: 60 }).unwrap()
}

/// Two models with identical serving shape but different weights — the
/// hot-swap pair (same fixture as `cluster_parity.rs`).
fn trained_pair() -> (Pipeline, Pipeline, Dataset) {
    let ds = letter(13);
    let other = letter(31);
    assert_eq!(ds.dim(), other.dim());
    let mut a = Pipeline::builder().seed(7).samples(24).i_bits(4).build().unwrap();
    a.fit(&ds.train_x, &ds.train_y).unwrap();
    let mut b = Pipeline::builder().seed(7).samples(24).i_bits(4).build().unwrap();
    b.fit(&other.train_x, &other.train_y).unwrap();
    (a, b, ds)
}

/// After any reply, the response channel must be spent: a second
/// bounded wait may time out or see the dropped sender, but another
/// reply would be a duplicate — the exactly-once violation this
/// harness exists to catch.
macro_rules! assert_spent {
    ($probe:expr, $($ctx:tt)+) => {
        assert!(
            matches!($probe, Err(ClusterError::WaitTimeout | ClusterError::ShuttingDown)),
            $($ctx)+
        )
    };
}

/// The flagship: concurrent clients + a hot-swapping publisher over a
/// faulted score cluster. Every accepted request is answered exactly
/// once within its bound, completions are bit-identical to the version
/// that scored them, and the snapshot reconciles with restarts.
#[test]
fn chaos_score_cluster_recovers_and_loses_nothing() {
    silence_injected_panics();
    let rate = fault_rate();
    let (pipe_a, pipe_b, ds) = trained_pair();
    let want_a = pipe_a.predict(&ds.test_x).unwrap();
    let want_b = pipe_b.predict(&ds.test_x).unwrap();
    let scorer_a = pipe_a.scorer(ds.dim()).unwrap();
    let scorer_b = pipe_b.scorer(ds.dim()).unwrap();
    let test = ds.test_x.to_dense();
    let rows = test.rows();

    for shards in shard_counts() {
        let cluster = pipe_a.cluster(ds.dim(), chaos_cfg(shards, rate)).unwrap();
        let n_clients = 3usize;
        let per_client = 250usize;
        let swaps = 12usize;
        let (ok, panicked, deadline) = std::thread::scope(|s| {
            // Publisher: alternate B, A, B, … so odd versions are model
            // A and even versions are model B — hot swaps keep landing
            // while workers die and respawn.
            let publisher = s.spawn(|| {
                for i in 0..swaps {
                    let next = if i % 2 == 0 { scorer_b.clone() } else { scorer_a.clone() };
                    cluster.publish(next).unwrap();
                    std::thread::sleep(Duration::from_millis(2));
                }
            });
            let clients: Vec<_> = (0..n_clients)
                .map(|c| {
                    let cluster = &cluster;
                    let test = &test;
                    let (want_a, want_b) = (&want_a, &want_b);
                    s.spawn(move || {
                        let (mut ok, mut panicked, mut deadline) = (0u64, 0u64, 0u64);
                        for i in 0..per_client {
                            let row = (c * per_client + i) % rows;
                            // Every 7th request carries an already-
                            // expired deadline: it must come back as
                            // the typed DeadlineExceeded, not hang and
                            // not burn compute.
                            let sub = if i % 7 == 3 {
                                cluster.submit_with_deadline(
                                    row as u64,
                                    test.row(row),
                                    Duration::ZERO,
                                )
                            } else {
                                cluster.submit(row as u64, test.row(row))
                            };
                            let sub = match sub {
                                Ok(sub) => sub,
                                Err(ClusterError::QueueFull | ClusterError::Shed { .. }) => {
                                    continue
                                }
                                Err(e) => panic!("unexpected submit error: {e}"),
                            };
                            match sub.wait_timeout(Duration::from_secs(30)) {
                                Ok(resp) => {
                                    assert_eq!(resp.id, row as u64);
                                    let want = if resp.version % 2 == 1 {
                                        want_a[row]
                                    } else {
                                        want_b[row]
                                    };
                                    assert_eq!(
                                        resp.label, want,
                                        "shards={shards} row {row} version {} must be \
                                         bit-identical under chaos",
                                        resp.version
                                    );
                                    ok += 1;
                                }
                                Err(ClusterError::WorkerPanicked { message }) => {
                                    assert!(
                                        message.contains(INJECTED),
                                        "real bug behind the injection harness: {message}"
                                    );
                                    panicked += 1;
                                }
                                Err(ClusterError::DeadlineExceeded) => deadline += 1,
                                Err(e) => {
                                    panic!("client hung or lost a response (shards={shards}): {e}")
                                }
                            }
                            assert_spent!(
                                sub.wait_timeout(Duration::ZERO),
                                "duplicate response: shards={shards} row {row}"
                            );
                        }
                        (ok, panicked, deadline)
                    })
                })
                .collect();
            let mut totals = (0u64, 0u64, 0u64);
            for h in clients {
                let (o, p, d) = h.join().unwrap();
                totals = (totals.0 + o, totals.1 + p, totals.2 + d);
            }
            publisher.join().unwrap();
            totals
        });

        // Quiescent: every client waited out its own requests, so the
        // snapshot must reconcile exactly against the client tallies.
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, ok, "shards={shards}");
        assert_eq!(snap.panicked, panicked, "shards={shards}");
        assert_eq!(snap.deadline_expired, deadline, "shards={shards}");
        assert_eq!(snap.accepted(), ok + panicked + deadline, "shards={shards}");
        assert_eq!(snap.answered(), snap.accepted(), "shards={shards}");
        assert!(
            snap.reconciles(),
            "shards={shards} accounting must partition requests: {}",
            snap.render()
        );
        assert_eq!(snap.current_version, 1 + swaps as u64);
        let counted: u64 = snap.version_counts.iter().map(|&(_, c)| c).sum();
        assert_eq!(counted, snap.completed, "every completion tallied under some version");
        assert!(deadline > 0, "shards={shards} expired-deadline submits must be typed");
        if rate >= 0.05 {
            assert!(snap.panicked > 0, "shards={shards} rate {rate} must inject panics");
            assert!(snap.restarts > 0, "shards={shards} rate {rate} must exercise respawn");
        }
        if rate == 0.0 {
            assert_eq!(snap.panicked, 0, "shards={shards} zero rate injects nothing");
            assert_eq!(snap.restarts, 0, "shards={shards} zero rate respawns nothing");
        }
        cluster.shutdown();
    }
}

/// Sparse corpus for the query-mode chaos run.
fn corpus(rows: usize, dim: usize, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut b = CsrBuilder::new(dim);
    for _ in 0..rows {
        let mut row: Vec<(u32, f32)> = Vec::new();
        for i in 0..dim as u32 {
            if rng.uniform() < 0.3 {
                row.push((i, rng.lognormal(0.0, 1.0) as f32));
            }
        }
        if row.is_empty() {
            row.push((0, 1.0));
        }
        b.push_row(row);
    }
    b.finish()
}

/// Query mode under the same chaos mix: completed retrievals stay
/// bit-identical to direct index calls, faults come back typed, and
/// the snapshot reconciles.
#[test]
fn chaos_query_cluster_isolates_faults_and_stays_bit_identical() {
    silence_injected_panics();
    let rate = fault_rate();
    let idx = Arc::new(
        PackedLshIndex::build(
            Arc::new(corpus(120, 64, 5)),
            LshConfig { bands: 8, rows_per_band: 2, seed: 9 },
            8,
        )
        .unwrap(),
    );
    let params = QueryParams { probes: 1, min_agreement: 0.0 };
    let mut scratch = QueryScratch::new();
    for shards in shard_counts() {
        let cluster = QueryRouter::start(Arc::clone(&idx), params, chaos_cfg(shards, rate)).unwrap();
        let (mut ok, mut panicked, mut deadline) = (0u64, 0u64, 0u64);
        for pass in 0..3u64 {
            for row in 0..idx.len() {
                let q = idx.corpus().row(row);
                let id = pass * 1000 + row as u64;
                let sub = if row % 7 == 3 {
                    cluster.submit_with_deadline(id, q, 5, Duration::ZERO)
                } else {
                    cluster.submit(id, q, 5)
                };
                let sub = match sub {
                    Ok(sub) => sub,
                    Err(ClusterError::QueueFull | ClusterError::Shed { .. }) => continue,
                    Err(e) => panic!("unexpected submit error: {e}"),
                };
                match sub.wait_timeout(Duration::from_secs(30)) {
                    Ok(resp) => {
                        assert_eq!(
                            resp.hits,
                            idx.query_with(q, 5, params, &mut scratch),
                            "shards={shards} row {row} must stay bit-identical under chaos"
                        );
                        ok += 1;
                    }
                    Err(ClusterError::WorkerPanicked { message }) => {
                        assert!(
                            message.contains(INJECTED),
                            "real bug behind the injection harness: {message}"
                        );
                        panicked += 1;
                    }
                    Err(ClusterError::DeadlineExceeded) => deadline += 1,
                    Err(e) => panic!("client hung or lost a response (shards={shards}): {e}"),
                }
                assert_spent!(
                    sub.wait_timeout(Duration::ZERO),
                    "duplicate response: shards={shards} row {row}"
                );
            }
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, ok, "shards={shards}");
        assert_eq!(snap.panicked, panicked, "shards={shards}");
        assert_eq!(snap.deadline_expired, deadline, "shards={shards}");
        assert!(snap.reconciles(), "shards={shards}: {}", snap.render());
        assert!(deadline > 0, "shards={shards} expired-deadline submits must be typed");
        if rate >= 0.05 {
            assert!(snap.panicked > 0, "shards={shards} rate {rate} must inject panics");
        }
        if rate >= 0.2 {
            assert!(snap.restarts > 0, "shards={shards} rate {rate} must exercise respawn");
        }
        cluster.shutdown();
    }
}

/// Every answered request kills its worker — the harshest supervision
/// load — and shutdown races the carnage.
fn death_heavy(seed: u64) -> FaultPlan {
    FaultPlan {
        seed,
        panic_rate: 0.3,
        death_rate: 1.0,
        slow_rate: 0.0,
        slow: Duration::ZERO,
        stall_rate: 0.0,
        stall: Duration::ZERO,
    }
}

/// Shutdown while every worker keeps dying must terminate (no
/// deadlock: the supervisor joins corpses and stops respawning past
/// the stop flag) and still answer every accepted request — score mode.
#[test]
fn chaos_shutdown_races_worker_deaths_without_deadlock_score() {
    silence_injected_panics();
    let (pipe_a, _, ds) = trained_pair();
    let test = ds.test_x.to_dense();
    let cfg = ClusterConfig {
        shards: 2,
        queue_cap: 1024,
        shed_watermark: None,
        steal: true,
        faults: Some(death_heavy(fault_seed())),
    };
    let cluster = pipe_a.cluster(ds.dim(), cfg).unwrap();
    let mut pending = Vec::new();
    for i in 0..96u64 {
        match cluster.submit(i, test.row((i as usize) % test.rows())) {
            Ok(sub) => pending.push(sub),
            Err(ClusterError::QueueFull | ClusterError::Shed { .. }) => {}
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let accepted = pending.len() as u64;
    assert!(accepted > 0);
    cluster.shutdown();
    let (mut ok, mut panicked) = (0u64, 0u64);
    for sub in pending {
        match sub.wait() {
            Ok(_) => ok += 1,
            Err(ClusterError::WorkerPanicked { message }) => {
                assert!(message.contains(INJECTED), "{message}");
                panicked += 1;
            }
            Err(e) => panic!("accepted request lost across shutdown-during-death: {e}"),
        }
    }
    assert_eq!(ok + panicked, accepted, "every accepted request answered exactly once");
}

/// The same shutdown-during-death race for the query router.
#[test]
fn chaos_shutdown_races_worker_deaths_without_deadlock_query() {
    silence_injected_panics();
    let idx = Arc::new(
        PackedLshIndex::build(
            Arc::new(corpus(60, 48, 11)),
            LshConfig { bands: 8, rows_per_band: 2, seed: 9 },
            8,
        )
        .unwrap(),
    );
    let params = QueryParams { probes: 1, min_agreement: 0.0 };
    let cfg = ClusterConfig {
        shards: 2,
        queue_cap: 1024,
        shed_watermark: None,
        steal: true,
        faults: Some(death_heavy(fault_seed())),
    };
    let cluster = QueryRouter::start(Arc::clone(&idx), params, cfg).unwrap();
    let mut pending = Vec::new();
    for i in 0..96u64 {
        match cluster.submit(i, idx.corpus().row((i as usize) % idx.len()), 5) {
            Ok(sub) => pending.push(sub),
            Err(ClusterError::QueueFull | ClusterError::Shed { .. }) => {}
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let accepted = pending.len() as u64;
    assert!(accepted > 0);
    cluster.shutdown();
    let (mut ok, mut panicked) = (0u64, 0u64);
    for sub in pending {
        match sub.wait() {
            Ok(_) => ok += 1,
            Err(ClusterError::WorkerPanicked { message }) => {
                assert!(message.contains(INJECTED), "{message}");
                panicked += 1;
            }
            Err(e) => panic!("accepted request lost across shutdown-during-death: {e}"),
        }
    }
    assert_eq!(ok + panicked, accepted, "every accepted request answered exactly once");
}
