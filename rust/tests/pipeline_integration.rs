//! End-to-end coordinator integration: the PJRT-backed hashing service
//! must agree with the native backend (up to rare f32/f64 argmin flips),
//! and offline-trained weights must serve identically through the fused
//! `hash_score` artifact.
//!
//! Skips when `make artifacts` has not run.

use std::time::Duration;

use minmax::coordinator::{HashService, NativeBackend, PjrtBackend, ServiceConfig};
use minmax::runtime::{default_artifacts_dir, pjrt_enabled};
use minmax::util::rng::Pcg64;

fn artifacts_or_skip() -> Option<std::path::PathBuf> {
    if !pjrt_enabled() {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_service_agrees_with_native_service() {
    let Some(dir) = artifacts_or_skip() else { return };
    // cws_hash_small artifact: B=16, D=64, K=64 (see aot.py VARIANTS).
    let cfg = ServiceConfig {
        seed: 99,
        k: 64,
        dim: 64,
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        queue_cap: 1024,
    };
    let pjrt = HashService::start(cfg.clone(), PjrtBackend::new(dir, "cws_hash_small"))
        .expect("start pjrt service");
    let native = HashService::start(cfg, NativeBackend).expect("start native service");

    let mut rng = Pcg64::new(4242);
    let n = 48;
    let vectors: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v: Vec<f32> = (0..64)
                .map(|_| {
                    if rng.uniform() < 0.4 {
                        0.0
                    } else {
                        rng.lognormal(0.0, 1.0) as f32
                    }
                })
                .collect();
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            v
        })
        .collect();

    let mut agree = 0usize;
    let mut total = 0usize;
    for (i, v) in vectors.iter().enumerate() {
        let a = pjrt.hash_blocking(i as u64, v).unwrap();
        let b = native.hash_blocking(i as u64, v).unwrap();
        assert_eq!(a.samples.len(), 64);
        assert_eq!(b.samples.len(), 64);
        for (sa, sb) in a.samples.iter().zip(&b.samples) {
            total += 1;
            if sa.i_star == sb.i_star {
                agree += 1;
            }
        }
    }
    assert!(
        agree as f64 >= 0.99 * total as f64,
        "PJRT vs native agreement {agree}/{total}"
    );

    let snap = pjrt.metrics().snapshot();
    assert_eq!(snap.requests, n as u64);
    assert!(snap.batches >= 1);
    pjrt.shutdown();
    native.shutdown();
}

#[test]
fn pjrt_service_batches_under_load() {
    let Some(dir) = artifacts_or_skip() else { return };
    let cfg = ServiceConfig {
        seed: 7,
        k: 64,
        dim: 64,
        max_batch: 16,
        max_wait: Duration::from_millis(10),
        queue_cap: 4096,
    };
    let svc = HashService::start(cfg, PjrtBackend::new(dir, "cws_hash_small"))
        .expect("start pjrt service");
    // Fire a burst, then collect: the dynamic batcher should aggregate.
    let v: Vec<f32> = (1..=64).map(|i| i as f32 / 8.0).collect();
    let rxs: Vec<_> = (0..64).map(|i| svc.submit(i, v.clone()).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.samples.len(), 64);
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.requests, 64);
    assert!(
        snap.batches < 64,
        "expected batching, got {} batches for 64 requests",
        snap.batches
    );
    svc.shutdown();
}

#[test]
fn offline_weights_serve_identically_via_hash_score_artifact() {
    let Some(dir) = artifacts_or_skip() else { return };
    use minmax::coordinator::{export_scorer_weights, hash_dataset, PipelineConfig};
    use minmax::data::synth::{generate, SynthConfig};
    use minmax::runtime::{literal_f32, Engine};
    use minmax::svm::{LinearOvR, LinearSvmParams};

    // hash_score artifact: B=64, D=256, K=128, bits=8, classes=16.
    let engine = Engine::load_subset(&dir, &["hash_score"]).unwrap();
    let spec = engine.spec("hash_score").unwrap().clone();
    let (b, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.inputs[1].shape[0];
    let codes = spec.inputs[4].shape[1];
    let classes_cap = spec.inputs[4].shape[2];

    // Build a dataset matching the artifact's D by zero-padding youtube (10 classes)
    // (64-dim) into D=256.
    let mut ds =
        generate("youtube", SynthConfig { seed: 31, n_train: 150, n_test: b }).unwrap();
    let pad = |m: &minmax::data::Matrix| -> minmax::data::Matrix {
        let dense = m.to_dense();
        let mut out = minmax::data::Dense::zeros(dense.rows(), d);
        for i in 0..dense.rows() {
            out.row_mut(i)[..dense.cols()].copy_from_slice(dense.row(i));
        }
        minmax::data::Matrix::Dense(out)
    };
    ds.train_x = pad(&ds.train_x);
    ds.test_x = pad(&ds.test_x);
    assert!(ds.n_classes() <= classes_cap);

    let seed = 555u64;
    let cfg = PipelineConfig { seed, k, i_bits: 8, t_bits: 0 };
    let hashed = hash_dataset(&ds, &cfg).expect("valid expansion");
    let c = 1.0;
    let w = export_scorer_weights(&hashed.train, &ds.train_y, classes_cap, &hashed.expansion, c);

    // Native predictions (OvR argmax on expanded features).
    let p = LinearSvmParams { c, ..Default::default() };
    let model = LinearOvR::train(&hashed.train, &ds.train_y, classes_cap, &p);
    let native_preds: Vec<i32> =
        (0..hashed.test.rows()).map(|i| model.predict_on(&hashed.test, i)).collect();

    // PJRT serving: one fused hash+score execute on the raw test batch.
    let (r, cc, beta) = minmax::cws::materialize_params(seed, d, k);
    let test_dense = ds.test_x.to_dense();
    let outs = engine
        .run_decoded(
            "hash_score",
            &[
                literal_f32(test_dense.data(), &[b, d]).unwrap(),
                literal_f32(&r, &[k, d]).unwrap(),
                literal_f32(&cc, &[k, d]).unwrap(),
                literal_f32(&beta, &[k, d]).unwrap(),
                literal_f32(&w, &[k, codes, classes_cap]).unwrap(),
            ],
        )
        .unwrap();
    let scores = outs[0].as_f32().unwrap();
    let mut agree = 0usize;
    for i in 0..b {
        let row = &scores[i * classes_cap..(i + 1) * classes_cap];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap()
            .0 as i32;
        if pred == native_preds[i] {
            agree += 1;
        }
    }
    assert!(
        agree * 100 >= b * 95,
        "serving path agrees on {agree}/{b} predictions"
    );
}
