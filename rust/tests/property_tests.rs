//! Property-based tests over the coordinator-stack invariants, using the
//! from-scratch harness in `minmax::util::prop` (replay a failing case
//! with `MINMAX_PROP_SEED=<seed>`).

use minmax::cws::{collision_fraction, CwsHasher, Scheme};
use minmax::data::dense::Dense;
use minmax::data::sparse::{dot, Csr, CsrBuilder};
use minmax::features::Expansion;
use minmax::kernels::{dense_minmax, KernelKind};
use minmax::util::json::Json;
use minmax::util::prop::{check, close, ensure, Gen};

fn gen_csr(g: &mut Gen, rows: usize, cols: usize, zero_frac: f64) -> Csr {
    let mut b = CsrBuilder::new(cols);
    for _ in 0..rows {
        let v = g.nonneg_vec(cols, zero_frac);
        b.push_row(
            v.iter().enumerate().filter(|(_, &x)| x != 0.0).map(|(i, &x)| (i as u32, x)).collect(),
        );
    }
    b.finish()
}

#[test]
fn prop_kernels_symmetric_and_bounded() {
    check("kernels-symmetric-bounded", 150, |g| {
        let dim = g.usize_in(1, 128);
        let u = g.nonneg_vec(dim, 0.4);
        let v = g.nonneg_vec(dim, 0.4);
        for k in [
            KernelKind::Linear,
            KernelKind::MinMax,
            KernelKind::Intersection,
            KernelKind::Resemblance,
            KernelKind::Chi2,
        ] {
            let a = k.eval_dense(&u, &v);
            let b = k.eval_dense(&v, &u);
            close(a, b, 1e-10, k.name())?;
            ensure(a.is_finite(), "finite")?;
        }
        let mm = dense_minmax(&u, &v);
        ensure((0.0..=1.0).contains(&mm), "minmax in [0,1]")?;
        // Cauchy-like bound: intersection <= min(l1 norms).
        let inter = KernelKind::Intersection.eval_dense(&u, &v);
        let l1u: f64 = u.iter().map(|&x| x as f64).sum();
        let l1v: f64 = v.iter().map(|&x| x as f64).sum();
        ensure(inter <= l1u.min(l1v) + 1e-6, "intersection bound")
    });
}

#[test]
fn prop_sparse_dense_kernel_agreement() {
    check("sparse-dense-agreement", 100, |g| {
        let dim = g.usize_in(1, 200);
        let u = g.nonneg_vec(dim, 0.6);
        let v = g.nonneg_vec(dim, 0.6);
        let d = Dense::from_rows(&[&u, &v]);
        let s = Csr::from_dense(&d);
        for k in [KernelKind::Linear, KernelKind::MinMax, KernelKind::Chi2, KernelKind::Resemblance] {
            close(
                k.eval_dense(&u, &v),
                k.eval_sparse(s.row(0), s.row(1)),
                1e-6,
                k.name(),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_minmax_triangle_like_monotonicity() {
    // Scaling both vectors by the same positive factor leaves K_MM
    // unchanged (scale invariance of the ratio).
    check("minmax-scale-invariance", 100, |g| {
        let dim = g.usize_in(1, 64);
        let u = g.nonneg_vec(dim, 0.3);
        let v = g.nonneg_vec(dim, 0.3);
        let lam = g.f64_in(0.1, 10.0) as f32;
        let us: Vec<f32> = u.iter().map(|&x| x * lam).collect();
        let vs: Vec<f32> = v.iter().map(|&x| x * lam).collect();
        close(dense_minmax(&u, &v), dense_minmax(&us, &vs), 1e-5, "K(λu,λv)=K(u,v)")
    });
}

#[test]
fn prop_csr_invariants_under_ops() {
    check("csr-invariants", 80, |g| {
        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 50);
        let m = gen_csr(g, rows, cols, 0.5);
        m.check_invariants().map_err(|e| e)?;
        // select + scale keep invariants.
        let idx: Vec<usize> = (0..rows).filter(|_| g.bool_p(0.5)).collect();
        let sel = m.select_rows(&idx);
        sel.check_invariants()?;
        let mut scaled = m.clone();
        let factors: Vec<f32> = (0..rows).map(|_| 0.5 + g.f64_in(0.0, 2.0) as f32).collect();
        scaled.scale_rows(&factors);
        scaled.check_invariants()?;
        // dense roundtrip is identity.
        ensure(Csr::from_dense(&m.to_dense()) == m, "dense roundtrip")
    });
}

#[test]
fn prop_cws_collision_tracks_kernel() {
    check("cws-collision-tracks-kernel", 25, |g| {
        let dim = g.usize_in(16, 96);
        let u = g.nonneg_vec(dim, 0.3);
        // Correlated second vector to spread K_MM over (0, 1).
        let v: Vec<f32> = u
            .iter()
            .map(|&x| {
                if g.bool_p(0.15) {
                    g.rng.lognormal(0.0, 1.0) as f32
                } else {
                    (x as f64 * g.rng.lognormal(0.0, 0.4)) as f32
                }
            })
            .collect();
        if !u.iter().any(|&x| x > 0.0) || !v.iter().any(|&x| x > 0.0) {
            return Ok(());
        }
        let truth = dense_minmax(&u, &v);
        let k = 1500;
        let h = CwsHasher::new(g.rng.next_u64(), k);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let full = collision_fraction(Scheme::FULL, &su, &sv);
        let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
        let tol = 4.0 * (truth * (1.0 - truth) / k as f64).sqrt() + 0.02;
        close(full, truth, 1.0, "placeholder")?; // keep types happy
        ensure((full - truth).abs() <= tol, "full-scheme collision tracks K_MM")?;
        ensure((zero - truth).abs() <= tol + 0.02, "0-bit collision tracks K_MM")?;
        ensure(zero >= full - 1e-12, "dropping bits only adds collisions")
    });
}

#[test]
fn prop_scheme_truncation_monotone() {
    check("scheme-truncation-monotone", 40, |g| {
        let dim = g.usize_in(4, 64);
        let u = g.nonneg_vec(dim, 0.3);
        let v = g.nonneg_vec(dim, 0.3);
        if !u.iter().any(|&x| x > 0.0) || !v.iter().any(|&x| x > 0.0) {
            return Ok(());
        }
        let h = CwsHasher::new(g.rng.next_u64(), 400);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let full = collision_fraction(Scheme::FULL, &su, &sv);
        let one = collision_fraction(Scheme::ONE_BIT, &su, &sv);
        let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
        let i4 = collision_fraction(Scheme::with_i_bits(4), &su, &sv);
        let i1 = collision_fraction(Scheme::with_i_bits(1), &su, &sv);
        ensure(full <= one + 1e-12, "full <= 1-bit")?;
        ensure(one <= zero + 1e-12, "1-bit <= 0-bit")?;
        ensure(zero <= i4 + 1e-12, "0-bit <= i4")?;
        ensure(i4 <= i1 + 1e-12, "i4 <= i1")
    });
}

#[test]
fn prop_expansion_inner_product_counts_collisions() {
    check("expansion-ip-collisions", 40, |g| {
        let dim = g.usize_in(2, 48);
        let u = g.nonneg_vec(dim, 0.2);
        let v = g.nonneg_vec(dim, 0.2);
        if !u.iter().any(|&x| x > 0.0) || !v.iter().any(|&x| x > 0.0) {
            return Ok(());
        }
        let k = 1 << g.usize_in(3, 7);
        let bits = *g.choose(&[1u8, 2, 4, 8]);
        let e = Expansion::new(k, bits);
        let h = CwsHasher::new(g.rng.next_u64(), k);
        let (su, sv) = (h.hash_dense(&u), h.hash_dense(&v));
        let m = e.expand(&[Some(su.clone()), Some(sv.clone())]);
        m.check_invariants()?;
        ensure(m.row(0).nnz() == k, "exactly k ones")?;
        let ip = dot(m.row(0), m.row(1));
        let coll = collision_fraction(e.scheme(), &su, &sv) * k as f64;
        close(ip, coll, 1e-9, "⟨φ(u),φ(v)⟩ = collisions")
    });
}

#[test]
fn prop_linear_svm_separates_separable() {
    check("linear-svm-separable", 20, |g| {
        let dim = g.usize_in(2, 16);
        let n = 2 * g.usize_in(8, 30);
        let mut b = CsrBuilder::new(dim);
        let mut y = Vec::new();
        // Two well-separated lognormal clusters.
        let c1: Vec<f32> = (0..dim).map(|_| 3.0 + g.rng.uniform_f32()).collect();
        let c0: Vec<f32> = (0..dim).map(|_| 0.3 * g.rng.uniform_f32()).collect();
        for i in 0..n {
            let c = if i % 2 == 0 { &c1 } else { &c0 };
            let row: Vec<(u32, f32)> = c
                .iter()
                .enumerate()
                .map(|(j, &x)| (j as u32, (x as f64 * g.rng.lognormal(0.0, 0.1)) as f32))
                .collect();
            b.push_row(row);
            y.push(if i % 2 == 0 { 1 } else { -1 });
        }
        let x = b.finish();
        let m = minmax::svm::linear::train_binary(
            &x,
            &y,
            &minmax::svm::LinearSvmParams { c: 10.0, ..Default::default() },
        );
        let errs = (0..n).filter(|&i| m.predict(x.row(i)) != y[i]).count();
        ensure(errs == 0, "separable data fully separated")
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        if depth == 0 || g.bool_p(0.4) {
            match g.usize_in(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool_p(0.5)),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 1000.0).round() / 1000.0),
                _ => Json::Str(format!("s{}-\"esc\"\n{}", g.rng.next_u64() % 97, depth)),
            }
        } else if g.bool_p(0.5) {
            Json::Arr((0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1)).collect())
        } else {
            let mut o = Json::obj();
            for i in 0..g.usize_in(0, 4) {
                o.set(&format!("k{i}"), gen_json(g, depth - 1));
            }
            o
        }
    }
    check("json-roundtrip", 120, |g| {
        let j = gen_json(g, 3);
        let s = j.to_string();
        let back = Json::parse(&s).map_err(|e| format!("parse: {e} in {s}"))?;
        ensure(back == j, "roundtrip equality")?;
        let pretty = Json::parse(&j.to_pretty()).map_err(|e| e)?;
        ensure(pretty == j, "pretty roundtrip equality")
    });
}

#[test]
fn prop_libsvm_roundtrip_random_matrices() {
    check("libsvm-roundtrip", 60, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(1, 30);
        let m = gen_csr(g, rows, cols, 0.6);
        let labels: Vec<i32> = (0..rows).map(|_| g.usize_in(0, 5) as i32 - 2).collect();
        let mut buf = Vec::new();
        minmax::data::libsvm::write_to(&mut buf, &m, &labels).map_err(|e| e.to_string())?;
        let back = minmax::data::libsvm::read_from(buf.as_slice(), cols)
            .map_err(|e| e)?;
        ensure(back.labels == labels, "labels roundtrip")?;
        ensure(back.features == m, "features roundtrip")
    });
}

#[test]
fn prop_kernel_matrix_sym_equals_rect() {
    check("gram-sym-equals-rect", 25, |g| {
        let n = g.usize_in(2, 16);
        let dim = g.usize_in(1, 24);
        let mut d = Dense::zeros(n, dim);
        for i in 0..n {
            let v = g.nonneg_vec(dim, 0.3);
            d.row_mut(i).copy_from_slice(&v);
        }
        let m = minmax::data::Matrix::Dense(d);
        let kern = *g.choose(&[KernelKind::MinMax, KernelKind::Linear, KernelKind::Chi2]);
        let full = minmax::kernels::matrix::kernel_matrix(kern, &m, &m);
        let sym = minmax::kernels::matrix::kernel_matrix_sym(kern, &m);
        for i in 0..n {
            for j in 0..n {
                close(full.get(i, j) as f64, sym.get(i, j) as f64, 1e-6, "cell")?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_service_responds_to_every_request() {
    check("service-total-responses", 8, |g| {
        let dim = g.usize_in(4, 32);
        let k = g.usize_in(2, 24);
        let svc = minmax::coordinator::HashService::start(
            minmax::coordinator::ServiceConfig {
                seed: g.rng.next_u64(),
                k,
                dim,
                max_batch: g.usize_in(1, 8),
                max_wait: std::time::Duration::from_micros(g.usize_in(10, 2000) as u64),
                queue_cap: 64,
            },
            minmax::coordinator::NativeBackend,
        )
        .map_err(|e| format!("service start: {e}"))?;
        let n = g.usize_in(1, 40);
        let mut pending = Vec::new();
        for i in 0..n {
            let mut v = g.nonneg_vec(dim, 0.5);
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            loop {
                match svc.submit(i as u64, v.clone()) {
                    Ok(rx) => {
                        pending.push((i, rx));
                        break;
                    }
                    Err(minmax::coordinator::SubmitError::QueueFull) => std::thread::yield_now(),
                    Err(e) => return Err(format!("{e}")),
                }
            }
        }
        for (i, rx) in pending {
            let resp = rx
                .recv()
                .map_err(|_| "dropped response")?
                .map_err(|e| format!("worker error: {e}"))?;
            ensure(resp.id == i as u64, "response id matches")?;
            ensure(resp.samples.len() == k, "k samples")?;
        }
        Ok(())
    });
}
