//! Retrieval-engine parity: the banded b-bit LSH index must be a pure
//! execution change over per-row CWS hashing plus exact re-ranking.
//!
//! * The batch-built index (parallel engine, packed slab, open-addressed
//!   band tables) produces **bit-identical** buckets to hashing each row
//!   one at a time with `CwsHasher` and grouping by band tuple — at any
//!   `MINMAX_THREADS` / `MINMAX_SIMD` setting (the CI matrix).
//! * Multi-probe lookup is superset-monotone in the probe count.
//! * At a lossless truncation width the packed index and the legacy
//!   FNV-keyed index agree exactly — candidates and ranked top-k.
//! * Measured recall@10 tracks the banding S-curve `1 − (1 − s^r)^b`.
//! * The coordinator `query` service is bit-identical to direct index
//!   calls at every shard count, before and after a hot swap.

use std::collections::HashMap;
use std::sync::Arc;

use minmax::coordinator::{ClusterConfig, ClusterError, QueryRouter};
use minmax::cws::{
    CwsHasher, LshConfig, LshIndex, PackedLshIndex, QueryParams, QueryScratch,
};
use minmax::data::sparse::{Csr, CsrBuilder};
use minmax::kernels::sparse_minmax;
use minmax::util::rng::Pcg64;

/// Planted corpus: `groups` clusters of `per_group` near-duplicates
/// over `dim` columns. `sigma` is the per-weight jitter (small sigma ⇒
/// high within-group min-max similarity).
fn corpus(groups: usize, per_group: usize, dim: usize, sigma: f64, seed: u64) -> Csr {
    let mut rng = Pcg64::new(seed);
    let mut b = CsrBuilder::new(dim);
    for _ in 0..groups {
        let mut proto: Vec<(u32, f32)> = Vec::new();
        for i in 0..dim {
            if rng.uniform() < 0.3 {
                proto.push((i as u32, rng.lognormal(0.0, 1.0) as f32));
            }
        }
        let proto = if proto.is_empty() { vec![(0, 1.0)] } else { proto };
        for _ in 0..per_group {
            b.push_row(
                proto
                    .iter()
                    .map(|&(w, v)| (w, (v as f64 * rng.lognormal(0.0, sigma)) as f32))
                    .collect(),
            );
        }
    }
    b.finish()
}

/// Shard counts under test: `MINMAX_TEST_SHARDS` pins one (the CI
/// matrix), default sweeps both.
fn shard_counts() -> Vec<usize> {
    match std::env::var("MINMAX_TEST_SHARDS") {
        Ok(s) => vec![s.trim().parse().expect("MINMAX_TEST_SHARDS must be a shard count")],
        Err(_) => vec![1, 4],
    }
}

/// Reference candidate sets from first principles: hash every row
/// individually (single-row path — no batching, no slab), truncate
/// `i*` to `bits`, group rows by exact band tuple, and take the union
/// of the query row's groups. This is what the banded index *means*;
/// the packed index must reproduce it bit-for-bit whenever truncation
/// is collision-free over the corpus (guaranteed here by `dim ≤ 2^bits`).
fn reference_candidates(c: &Csr, cfg: LshConfig, bits: u8) -> Vec<Vec<u32>> {
    let hasher = CwsHasher::new(cfg.seed, cfg.k());
    let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    let tuples: Vec<Vec<u32>> = (0..c.rows())
        .map(|i| hasher.hash_sparse(c.row(i)).iter().map(|s| s.i_star & mask).collect())
        .collect();
    let mut groups: Vec<HashMap<&[u32], Vec<u32>>> = vec![HashMap::new(); cfg.bands];
    for (row, tuple) in tuples.iter().enumerate() {
        for (band, chunk) in tuple.chunks(cfg.rows_per_band).enumerate() {
            groups[band].entry(chunk).or_default().push(row as u32);
        }
    }
    (0..c.rows())
        .map(|row| {
            let mut cands: Vec<u32> = tuples[row]
                .chunks(cfg.rows_per_band)
                .enumerate()
                .flat_map(|(band, chunk)| groups[band][chunk].iter().copied())
                .collect();
            cands.sort_unstable();
            cands.dedup();
            cands
        })
        .collect()
}

#[test]
fn batched_index_matches_per_row_hashing() {
    let c = corpus(60, 6, 200, 0.1, 42);
    let cfg = LshConfig { bands: 8, rows_per_band: 3, seed: 99 };
    // dim = 200 ≤ 2^8, so 8-bit truncation cannot collide and the
    // reference grouping is exact for the packed index too.
    let want = reference_candidates(&c, cfg, 8);
    let arc = Arc::new(c);
    let packed = PackedLshIndex::build(Arc::clone(&arc), cfg, 8).unwrap();
    let legacy = LshIndex::try_build(Arc::clone(&arc), cfg).unwrap();
    let exact = QueryParams::default();
    let mut s = QueryScratch::new();
    for row in 0..arc.rows() {
        let got = packed.candidates_with(arc.row(row), exact, &mut s);
        assert_eq!(got, want[row], "packed row {row}");
        // The legacy index hashes FNV over untruncated tuples; with no
        // truncation collisions its buckets are the same partition.
        assert_eq!(legacy.candidates(arc.row(row)), want[row], "legacy row {row}");
    }
}

/// Miri-sized probe of the open-addressed band tables: a corpus small
/// enough for the interpreter (32 rows, dim 16 ≤ 2^8 so truncation is
/// collision-free) that still walks the whole build → pack → probe →
/// candidate-union path. The CI `miri` job runs exactly this test
/// (`MINMAX_THREADS=1`); natively it is a fast subset of
/// `batched_index_matches_per_row_hashing`.
#[test]
fn miri_band_table_probe() {
    let c = corpus(8, 4, 16, 0.1, 31);
    let cfg = LshConfig { bands: 4, rows_per_band: 2, seed: 13 };
    let want = reference_candidates(&c, cfg, 8);
    let arc = Arc::new(c);
    let idx = PackedLshIndex::build(Arc::clone(&arc), cfg, 8).unwrap();
    let mut s = QueryScratch::new();
    for row in 0..arc.rows() {
        let exact =
            idx.candidates_with(arc.row(row), QueryParams::default(), &mut s).to_vec();
        assert_eq!(exact, want[row], "row {row}");
        for probes in [1usize, 2] {
            let probed = idx
                .candidates_with(
                    arc.row(row),
                    QueryParams { probes, ..Default::default() },
                    &mut s,
                )
                .to_vec();
            assert!(
                exact.iter().all(|id| probed.binary_search(id).is_ok()),
                "row {row}: probing must only add candidates"
            );
        }
    }
}

#[test]
fn multi_probe_is_superset_monotone() {
    let c = corpus(40, 5, 300, 0.15, 7);
    let arc = Arc::new(c);
    let cfg = LshConfig { bands: 6, rows_per_band: 4, seed: 3 };
    let idx = PackedLshIndex::build(Arc::clone(&arc), cfg, 8).unwrap();
    let mut s = QueryScratch::new();
    for row in (0..arc.rows()).step_by(7) {
        let mut prev: Vec<u32> = Vec::new();
        for probes in [0usize, 1, 2, 4, 8, 16] {
            let got =
                idx.candidates_with(arc.row(row), QueryParams { probes, ..Default::default() }, &mut s)
                    .to_vec();
            assert!(
                prev.iter().all(|id| got.binary_search(id).is_ok()),
                "row {row}: probes={probes} dropped a candidate from a smaller probe count"
            );
            prev = got;
        }
    }
}

#[test]
fn packed_matches_legacy_topk_at_lossless_bits() {
    let c = corpus(50, 6, 150, 0.12, 11);
    let arc = Arc::new(c);
    let cfg = LshConfig { bands: 8, rows_per_band: 2, seed: 21 };
    let legacy = LshIndex::try_build(Arc::clone(&arc), cfg).unwrap();
    // dim = 150 < 2^16: 16-bit truncation is the identity on i*.
    let packed = PackedLshIndex::build(Arc::clone(&arc), cfg, 16).unwrap();
    let mut s = QueryScratch::new();
    for row in 0..arc.rows() {
        let q = arc.row(row);
        assert_eq!(
            packed.candidates_with(q, QueryParams::default(), &mut s).to_vec(),
            legacy.candidates(q),
            "row {row} candidates"
        );
        assert_eq!(packed.query(q, 5), legacy.query(q, 5), "row {row} top-k");
    }
}

#[test]
fn recall_tracks_s_curve_prediction() {
    // Tight groups (σ = 0.05 ⇒ within-group s ≈ 0.9): the S-curve at
    // b=16, r=2 predicts essentially certain candidacy, so recall@10
    // against exact brute force must be ≥ 0.9 and within noise of the
    // per-pair prediction average.
    let c = corpus(100, 12, 400, 0.05, 17);
    let arc = Arc::new(c);
    let cfg = LshConfig { bands: 16, rows_per_band: 2, seed: 5 };
    let idx = PackedLshIndex::build(Arc::clone(&arc), cfg, 8).unwrap();
    let top = 10usize;
    let mut s = QueryScratch::new();
    let (mut hits, mut total) = (0usize, 0usize);
    let mut predicted = 0.0f64;
    for row in (0..arc.rows()).step_by(11) {
        let q = arc.row(row);
        let mut truth: Vec<(u32, f64)> =
            (0..arc.rows()).map(|i| (i as u32, sparse_minmax(q, arc.row(i)))).collect();
        truth.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        truth.truncate(top);
        let got = idx.query_with(q, top, QueryParams::default(), &mut s);
        for &(id, sim) in &truth {
            total += 1;
            predicted += cfg.candidate_probability(sim);
            if got.iter().any(|&(g, _)| g == id) {
                hits += 1;
            }
        }
    }
    let recall = hits as f64 / total as f64;
    let expected = predicted / total as f64;
    assert!(recall >= 0.9, "recall@{top} = {recall:.3} must reach 0.9");
    assert!(
        recall >= expected - 0.05,
        "recall@{top} = {recall:.3} fell below S-curve prediction {expected:.3}"
    );
}

#[test]
fn query_router_matches_direct_index_across_shards_and_swaps() {
    let v1 = Arc::new(
        PackedLshIndex::build(
            Arc::new(corpus(30, 5, 120, 0.1, 23)),
            LshConfig { bands: 8, rows_per_band: 2, seed: 9 },
            8,
        )
        .unwrap(),
    );
    // Same banding/seed/bits/dim, fresh (larger) corpus snapshot: the
    // legitimate hot-swap payload.
    let v2 = Arc::new(
        PackedLshIndex::build(
            Arc::new(corpus(45, 5, 120, 0.1, 29)),
            LshConfig { bands: 8, rows_per_band: 2, seed: 9 },
            8,
        )
        .unwrap(),
    );
    let params = QueryParams { probes: 1, min_agreement: 0.0 };
    let mut s = QueryScratch::new();
    for shards in shard_counts() {
        let cfg = ClusterConfig {
            shards,
            queue_cap: 256,
            shed_watermark: None,
            steal: true,
            faults: None,
        };
        let cluster = QueryRouter::start(Arc::clone(&v1), params, cfg).unwrap();
        for row in 0..v1.len() {
            let q = v1.corpus().row(row);
            let resp = cluster.query_blocking(row as u64, q, 5).unwrap();
            assert_eq!(resp.hits, v1.query_with(q, 5, params, &mut s), "v1 row {row}");
            assert_eq!(resp.version, 1);
        }

        // Shape-incompatible indexes are rejected with a typed error.
        let bad = Arc::new(
            PackedLshIndex::build(
                Arc::clone(v2.corpus()),
                LshConfig { bands: 8, rows_per_band: 2, seed: 10 },
                8,
            )
            .unwrap(),
        );
        assert!(matches!(cluster.publish(bad), Err(ClusterError::ShapeMismatch(_))));
        assert_eq!(cluster.current_version(), 1);

        assert_eq!(cluster.publish(Arc::clone(&v2)).unwrap(), 2);
        for row in 0..v2.len() {
            let q = v2.corpus().row(row);
            let resp = cluster.query_blocking(row as u64, q, 5).unwrap();
            assert_eq!(resp.hits, v2.query_with(q, 5, params, &mut s), "v2 row {row}");
            assert_eq!(resp.version, 2);
        }
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, snap.requests);
        assert!(snap.reconciles(), "accounting must partition requests");
        assert_eq!(snap.version_counts.len(), 2);
        cluster.shutdown();
    }
}
