//! Cluster / layered-path parity for the sharded serving coordinator.
//!
//! The `coordinator::cluster::ScoreRouter` must be a pure execution
//! change over the fused scorer, exactly like the scorer is over the
//! layered path: predictions through the cluster are **bit-identical**
//! to `Pipeline::predict` — before a hot swap, during one (requests in
//! flight drain against the version that dequeued them), and after —
//! at every shard count. And a swap under load loses nothing: every
//! accepted request gets exactly one response, tagged with the version
//! that scored it, whose label matches that version's model.
//!
//! CI runs this under a `MINMAX_THREADS × MINMAX_TEST_SHARDS` matrix;
//! without the env var every test covers shard counts {1, 4} itself.

use minmax::coordinator::{ClusterConfig, ClusterError, ScoreRouter};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Dataset;
use minmax::pipeline::Pipeline;
use minmax::serve::Scorer;

/// Shard counts under test: `MINMAX_TEST_SHARDS` pins one (the CI
/// matrix), default sweeps both.
fn shard_counts() -> Vec<usize> {
    match std::env::var("MINMAX_TEST_SHARDS") {
        Ok(s) => vec![s.trim().parse().expect("MINMAX_TEST_SHARDS must be a shard count")],
        Err(_) => vec![1, 4],
    }
}

fn letter(data_seed: u64) -> Dataset {
    generate("letter", SynthConfig { seed: data_seed, n_train: 120, n_test: 60 }).unwrap()
}

/// Two models with identical serving shape (same sketcher seed, k,
/// dim) but different weights — the hot-swap pair.
fn trained_pair() -> (Pipeline, Pipeline, Dataset) {
    let ds = letter(13);
    let other = letter(31);
    assert_eq!(ds.dim(), other.dim());
    let mut a = Pipeline::builder().seed(7).samples(24).i_bits(4).build().unwrap();
    a.fit(&ds.train_x, &ds.train_y).unwrap();
    let mut b = Pipeline::builder().seed(7).samples(24).i_bits(4).build().unwrap();
    b.fit(&other.train_x, &other.train_y).unwrap();
    (a, b, ds)
}

fn cfg(shards: usize) -> ClusterConfig {
    ClusterConfig { shards, queue_cap: 512, shed_watermark: None, steal: true, faults: None }
}

#[test]
fn cluster_matches_pipeline_before_and_after_swap() {
    let (pipe_a, pipe_b, ds) = trained_pair();
    let want_a = pipe_a.predict(&ds.test_x).unwrap();
    let want_b = pipe_b.predict(&ds.test_x).unwrap();
    assert_ne!(want_a, want_b, "swap pair must actually disagree somewhere");
    let scorer_b = pipe_b.scorer(ds.dim()).unwrap();

    for shards in shard_counts() {
        let cluster = pipe_a.cluster(ds.dim(), cfg(shards)).unwrap();
        assert_eq!(cluster.current_version(), 1);

        // Before the swap: bit-identical to Pipeline::predict.
        assert_eq!(
            cluster.score_batch_blocking(&ds.test_x).unwrap(),
            want_a,
            "shards={shards} pre-swap"
        );

        // After: the new weights, still bit-identical, version tagged.
        let v = cluster.publish(scorer_b.clone()).unwrap();
        assert_eq!(v, 2);
        assert_eq!(
            cluster.score_batch_blocking(&ds.test_x).unwrap(),
            want_b,
            "shards={shards} post-swap"
        );
        let row0 = ds.test_x.to_dense();
        let resp = cluster.score_blocking(0, row0.row(0)).unwrap();
        assert_eq!(resp.version, 2);
        assert_eq!(resp.label, want_b[0]);

        // Everything accepted was answered.
        let snap = cluster.snapshot();
        assert_eq!(snap.completed, snap.requests);
        assert_eq!(snap.rejected + snap.shed, 0);
        assert!(snap.reconciles(), "accounting must partition requests");
        assert_eq!(snap.restarts, 0, "healthy run respawns nothing");
        assert_eq!(snap.current_version, 2);
        cluster.shutdown();
    }
}

#[test]
fn cluster_decisions_are_bit_identical_to_direct_scorer() {
    let (pipe_a, _, ds) = trained_pair();
    let direct = pipe_a.scorer(ds.dim()).unwrap();
    let test = ds.test_x.to_dense();
    for shards in shard_counts() {
        let cluster = pipe_a.cluster(ds.dim(), cfg(shards)).unwrap();
        let mut scratch = direct.scratch();
        let mut want = vec![0.0f64; direct.n_classes()];
        for i in 0..test.rows() {
            let resp = cluster.score_blocking(i as u64, test.row(i)).unwrap();
            direct.score_dense_into(test.row(i), &mut scratch, &mut want);
            assert_eq!(resp.decisions, want, "shards={shards} row {i}");
        }
        cluster.shutdown();
    }
}

/// Hot swap under load: publishers flip versions while clients hammer
/// submits. Every accepted request must get exactly one response whose
/// label is bit-identical to the model of the version that scored it —
/// in-flight requests drain on their dequeue-time version, none are
/// lost or re-scored.
#[test]
fn hot_swap_under_load_loses_nothing_and_scores_on_tagged_version() {
    let (pipe_a, pipe_b, ds) = trained_pair();
    let want_a = pipe_a.predict(&ds.test_x).unwrap();
    let want_b = pipe_b.predict(&ds.test_x).unwrap();
    let scorer_a = pipe_a.scorer(ds.dim()).unwrap();
    let scorer_b = pipe_b.scorer(ds.dim()).unwrap();
    let test = ds.test_x.to_dense();
    let rows = test.rows();

    for shards in shard_counts() {
        let cluster = pipe_a.cluster(ds.dim(), cfg(shards)).unwrap();
        let n_clients = 3usize;
        let per_client = 200usize;
        let swaps = 20usize;
        std::thread::scope(|s| {
            // Publisher: alternate B, A, B, … so versions 1,3,5,… are
            // model A and 2,4,6,… are model B.
            let publisher = s.spawn(|| {
                for i in 0..swaps {
                    let next =
                        if i % 2 == 0 { scorer_b.clone() } else { scorer_a.clone() };
                    cluster.publish(next).unwrap();
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            });
            let clients: Vec<_> = (0..n_clients)
                .map(|c| {
                    let cluster = &cluster;
                    let test = &test;
                    let (want_a, want_b) = (&want_a, &want_b);
                    s.spawn(move || {
                        let mut accepted = 0u64;
                        for i in 0..per_client {
                            let row = (c * per_client + i) % rows;
                            match cluster.submit(row as u64, test.row(row)) {
                                Ok(sub) => {
                                    accepted += 1;
                                    let resp = sub.wait().expect("accepted request lost");
                                    assert_eq!(resp.id, row as u64);
                                    let want = if resp.version % 2 == 1 {
                                        want_a[row]
                                    } else {
                                        want_b[row]
                                    };
                                    assert_eq!(
                                        resp.label, want,
                                        "shards={shards} row {row} version {}",
                                        resp.version
                                    );
                                }
                                Err(ClusterError::QueueFull) => {}
                                Err(e) => panic!("unexpected submit error: {e}"),
                            }
                        }
                        accepted
                    })
                })
                .collect();
            let total: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
            publisher.join().unwrap();
            assert!(total > 0);
            let snap = cluster.snapshot();
            // `requests` counts rejected submits too; what the clients
            // tallied is the accepted subset, and none may be lost.
            assert_eq!(snap.accepted(), total, "shards={shards}");
            assert_eq!(snap.completed, total, "shards={shards} zero loss");
            assert!(snap.reconciles(), "shards={shards} accounting partitions requests");
            assert_eq!(snap.current_version, 1 + swaps as u64);
            let counted: u64 = snap.version_counts.iter().map(|&(_, c)| c).sum();
            assert_eq!(counted, total, "every completion tallied under some version");
        });
        cluster.shutdown();
    }
}

/// Graceful shutdown drains: accepted-then-dropped cannot happen even
/// when shutdown races a full pipeline of queued work.
#[test]
fn shutdown_under_load_answers_every_accepted_request() {
    let (pipe_a, _, ds) = trained_pair();
    let test = ds.test_x.to_dense();
    for shards in shard_counts() {
        let cluster: ScoreRouter = pipe_a.cluster(ds.dim(), cfg(shards)).unwrap();
        let mut pending = Vec::new();
        for i in 0..400u64 {
            match cluster.submit(i, test.row((i as usize) % test.rows())) {
                Ok(sub) => pending.push((i, sub)),
                Err(ClusterError::QueueFull) => {}
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        let accepted = pending.len();
        cluster.shutdown();
        for (i, sub) in pending {
            let resp = sub.wait().expect("accepted request dropped at shutdown");
            assert_eq!(resp.id, i, "shards={shards}");
        }
        assert!(accepted > 0);
    }
}

/// A cloned-from-the-same-pipeline scorer publishes cleanly; a scorer
/// with a different sketcher seed is refused — replicas must stay
/// interchangeable.
#[test]
fn publish_shape_validation_is_enforced() {
    let (pipe_a, _, ds) = trained_pair();
    let cluster = pipe_a.cluster(ds.dim(), cfg(1)).unwrap();
    let mut other = Pipeline::builder().seed(8).samples(24).i_bits(4).build().unwrap();
    other.fit(&ds.train_x, &ds.train_y).unwrap();
    let wrong_seed: Scorer = other.scorer(ds.dim()).unwrap();
    assert!(matches!(cluster.publish(wrong_seed), Err(ClusterError::ShapeMismatch(_))));
    assert_eq!(cluster.current_version(), 1);
    cluster.shutdown();
}
