//! Bit-for-bit parity pins for the loop-inverted `SketchEngine`.
//!
//! The refactor's contract: transposing the parameter slabs, inverting
//! the loop order, and batching rows across threads must not change a
//! single output bit in the default (exact-math) mode. The reference
//! below IS the pre-refactor sampler — the original `j`-outer scalar
//! argmin over lazy `params_at` triples — reimplemented here so the
//! property holds against the spec, not against whatever the crate
//! currently does. Replay a failing property case with
//! `MINMAX_PROP_SEED=<seed>`.

use minmax::cws::engine::{fast_math_requested, sample_lazy, sketch_csr_with};
use minmax::cws::sampler::params_at;
use minmax::cws::{CwsHasher, CwsSample, DenseBatchHasher, SketchEngine};
use minmax::data::dense::Dense;
use minmax::data::sparse::{Csr, CsrBuilder};
use minmax::data::Matrix;
use minmax::sketch::Sketcher;
use minmax::util::prop::{check, ensure, Gen};
use minmax::util::rng::Pcg64;

/// The pre-refactor sampler, verbatim: for each sample j, scan the
/// nonzeros in order, keep the strictly-smallest `a` (first winner of a
/// tie), derive `(r, c, β)` lazily per `(j, i)`.
fn reference_sample(seed: u64, k: usize, indices: &[u32], values: &[f32]) -> Vec<CwsSample> {
    let ln_u: Vec<f64> = values.iter().map(|&v| (v as f64).ln()).collect();
    (0..k as u32)
        .map(|j| {
            let mut best_a = f64::INFINITY;
            let mut best = CwsSample { i_star: u32::MAX, t_star: 0 };
            for (&i, &lnu) in indices.iter().zip(&ln_u) {
                let (r, c, beta) = params_at(seed, j, i);
                let t = (lnu / r + beta).floor();
                let a = c * (-(r * (t - beta)) - r).exp();
                if a < best_a {
                    best_a = a;
                    best = CwsSample { i_star: i, t_star: t as i64 };
                }
            }
            best
        })
        .collect()
}

fn gen_sparse_vec(g: &mut Gen, dim: usize, zero_frac: f64) -> (Vec<u32>, Vec<f32>) {
    let v = g.nonneg_vec(dim, zero_frac);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for (i, &x) in v.iter().enumerate() {
        if x > 0.0 {
            indices.push(i as u32);
            values.push(x);
        }
    }
    if indices.is_empty() {
        indices.push(0);
        values.push(1.0);
    }
    (indices, values)
}

fn to_dense(dim: usize, indices: &[u32], values: &[f32]) -> Vec<f32> {
    let mut u = vec![0.0f32; dim];
    for (&i, &v) in indices.iter().zip(values) {
        u[i as usize] = v;
    }
    u
}

/// Bit-for-bit parity is only claimed in exact math mode. When the
/// operator opts into `MINMAX_FAST_MATH=1`, engine-backed paths
/// legitimately diverge on near-tie argmins, so the strict-equality
/// tests stand down (the fastmath agreement test in `cws::engine` still
/// covers that mode).
fn exact_mode() -> bool {
    !fast_math_requested()
}

#[test]
fn prop_engine_bit_identical_to_pre_refactor_sampler() {
    if !exact_mode() {
        eprintln!("skipped: MINMAX_FAST_MATH is set");
        return;
    }
    check("engine-vs-reference", 120, |g| {
        let seed = g.usize_in(0, 1 << 20) as u64;
        let k = g.usize_in(1, 64);
        let dim = g.usize_in(1, 96);
        let zero_frac = g.f64_in(0.0, 0.9);
        let (indices, values) = gen_sparse_vec(g, dim, zero_frac);
        let want = reference_sample(seed, k, &indices, &values);

        // Lazy facade (CwsHasher) — loop-inverted, params on the fly.
        let hasher = CwsHasher::new(seed, k);
        let dense = to_dense(dim, &indices, &values);
        ensure(hasher.hash_dense(&dense) == want, "hash_dense == reference")?;
        let ln_u: Vec<f64> = values.iter().map(|&v| (v as f64).ln()).collect();
        ensure(sample_lazy(seed, k, &indices, &ln_u) == want, "sample_lazy == reference")?;

        // Materialized engine — transposed slabs, same bits.
        let engine = SketchEngine::new(seed, k, dim).with_fast_math(false);
        ensure(engine.sketch_dense(&dense) == want, "engine dense == reference")?;
        let batch = DenseBatchHasher::new(seed, k, dim);
        ensure(batch.hash(&dense) == want, "batch facade == reference")
    });
}

#[test]
fn prop_sparse_paths_bit_identical() {
    if !exact_mode() {
        eprintln!("skipped: MINMAX_FAST_MATH is set");
        return;
    }
    check("engine-sparse-vs-reference", 80, |g| {
        let seed = g.usize_in(0, 1 << 20) as u64;
        let k = g.usize_in(1, 48);
        let dim = g.usize_in(1, 128);
        let (indices, values) = gen_sparse_vec(g, dim, g.f64_in(0.3, 0.95));
        let want = reference_sample(seed, k, &indices, &values);

        let mut b = CsrBuilder::new(dim);
        b.push_row(indices.iter().zip(&values).map(|(&i, &v)| (i, v)).collect());
        let m = b.finish();
        let hasher = CwsHasher::new(seed, k);
        ensure(hasher.hash_sparse(m.row(0)) == want, "hash_sparse == reference")?;
        let batch = hasher.dense_batch(dim);
        ensure(batch.hash_sparse(m.row(0)) == want, "batch sparse == reference")
    });
}

#[test]
fn golden_engine_slabs_match_params_at_pins() {
    // The cross-language golden constants pinned in
    // `cws::sampler::tests::golden_params_cross_language`, read back out
    // of the engine's transposed slabs: the refactor may not perturb a
    // single parameter bit.
    let cases: [(u64, u32, u32, f64, f64, f64); 3] = [
        (42, 0, 0, 2.1321342897249402, 2.34453352747202, 0.9619698314597537),
        (42, 3, 7, 0.9596960229776987, 1.5230354601677472, 0.4030703586081501),
        (2015, 127, 255, 2.5218182169423575, 2.662209577473352, 0.642316614160663),
    ];
    for (seed, j, i, er, ec, eb) in cases {
        let engine = SketchEngine::new(seed, (j + 1) as usize, (i + 1) as usize);
        let (r, c, b) = engine.params_slab(i as usize);
        assert_eq!(r[j as usize], er, "r({seed},{j},{i})");
        assert_eq!(c[j as usize], ec, "c({seed},{j},{i})");
        assert_eq!(b[j as usize], eb, "beta({seed},{j},{i})");
        // And the lazy derivation agrees with the slab, cell for cell.
        let (lr, lc, lb) = params_at(seed, j, i);
        assert_eq!((r[j as usize], c[j as usize], b[j as usize]), (lr, lc, lb));
    }
}

#[test]
fn chunked_parallel_is_thread_count_invariant() {
    let mut g = Gen { rng: Pcg64::new(0xC0FFEE), size: 1.0 };
    let dim = 64;
    let k = 32;
    let rows: Vec<Vec<f32>> = (0..57)
        .map(|_| {
            let mut v = g.nonneg_vec(dim, 0.5);
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            v
        })
        .collect();
    let refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
    let engine = SketchEngine::new(7, k, dim);
    let sequential = engine.sketch_rows_with_threads(&refs, 1);
    for threads in [2usize, 3, 4, 8, 16] {
        assert_eq!(
            sequential,
            engine.sketch_rows_with_threads(&refs, threads),
            "threads={threads}"
        );
    }
    // Per-row parity against the reference sampler (exact mode only).
    if exact_mode() {
        for (row, got) in refs.iter().zip(&sequential) {
            let indices: Vec<u32> = (0..dim as u32).filter(|&i| row[i as usize] > 0.0).collect();
            let values: Vec<f32> = indices.iter().map(|&i| row[i as usize]).collect();
            assert_eq!(*got, reference_sample(7, k, &indices, &values));
        }
    }
}

#[test]
fn minmax_threads_does_not_change_results() {
    // The env-driven default path (whatever MINMAX_THREADS is in this
    // process — CI runs the whole suite under =1 and =4) must agree
    // bit-for-bit with explicitly pinned 1- and 4-thread runs of the
    // same sharding substrate. Deliberately NO std::env::set_var here:
    // mutating the environment while the parallel test harness has
    // other threads calling env::var (default_threads,
    // fast_math_requested) is a data race on glibc.
    let mut g = Gen { rng: Pcg64::new(0xBEEF), size: 1.0 };
    let dim = 40;
    let mut b = CsrBuilder::new(dim);
    for i in 0..41 {
        if i % 7 == 3 {
            b.push_row(vec![]); // empty rows stay None under every thread count
        } else {
            let v = g.nonneg_vec(dim, 0.6);
            let mut entries: Vec<(u32, f32)> = v
                .iter()
                .enumerate()
                .filter(|(_, &x)| x > 0.0)
                .map(|(i, &x)| (i as u32, x))
                .collect();
            if entries.is_empty() {
                entries.push((0, 1.0));
            }
            b.push_row(entries);
        }
    }
    let m = Matrix::Sparse(b.finish());
    let hasher = CwsHasher::new(11, 16);
    let via_env_default = hasher.sketch_matrix(&m);
    let csr = m.as_csr().unwrap();
    for threads in [1usize, 4] {
        // The CwsHasher sparse arm, with the thread count pinned.
        let pinned = sketch_csr_with(csr, 16, threads, |row, scratch, out| {
            minmax::cws::engine::sample_lazy_sparse_with(11, 16, row, scratch, out);
        });
        assert_eq!(via_env_default, pinned, "threads={threads}");
    }
    assert!(via_env_default[3].is_none() && via_env_default[10].is_none());
    // And the result matches the sequential per-row reference (the lazy
    // sparse path is exact math regardless of MINMAX_FAST_MATH).
    for i in 0..csr.rows() {
        let row = csr.row(i);
        let want = if row.nnz() == 0 {
            None
        } else {
            Some(reference_sample(11, 16, row.indices, row.values))
        };
        assert_eq!(via_env_default[i], want, "row {i}");
    }
}

#[test]
fn sketch_csr_with_matches_sketcher_matrix() {
    let mut g = Gen { rng: Pcg64::new(0xD1CE), size: 1.0 };
    let dim = 32;
    let k = 12;
    let mut b = CsrBuilder::new(dim);
    for _ in 0..23 {
        let v = g.nonneg_vec(dim, 0.7);
        b.push_row(
            v.iter().enumerate().filter(|(_, &x)| x > 0.0).map(|(i, &x)| (i as u32, x)).collect(),
        );
    }
    let csr = b.finish();
    let batch = DenseBatchHasher::new(3, k, dim);
    for threads in [1usize, 4] {
        let direct = sketch_csr_with(&csr, k, threads, |row, scratch, out| {
            batch.engine().sketch_sparse_with(row, scratch, out);
        });
        let via_trait = batch.sketch_matrix(&Matrix::Sparse(csr.clone()));
        assert_eq!(direct, via_trait, "threads={threads}");
    }
}

#[test]
fn dense_and_sparse_matrix_forms_agree_through_the_batch_paths() {
    if !exact_mode() {
        eprintln!("skipped: MINMAX_FAST_MATH is set");
        return;
    }
    let mut g = Gen { rng: Pcg64::new(0xFEED), size: 1.0 };
    let dim = 24;
    let rows: Vec<Vec<f32>> = (0..19)
        .map(|_| {
            let mut v = g.nonneg_vec(dim, 0.4);
            if !v.iter().any(|&x| x > 0.0) {
                v[0] = 1.0;
            }
            v
        })
        .collect();
    let row_refs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
    let d = Dense::from_rows(&row_refs);
    let s = Csr::from_dense(&d);
    let hasher = CwsHasher::new(21, 20);
    let dense_out = hasher.sketch_matrix(&Matrix::Dense(d));
    let sparse_out = hasher.sketch_matrix(&Matrix::Sparse(s));
    assert_eq!(dense_out, sparse_out);
    let batch = hasher.dense_batch(dim);
    let batched = batch.sketch_dense_batch(&row_refs);
    for (i, out) in dense_out.iter().enumerate() {
        assert_eq!(out.as_ref().unwrap(), &batched[i]);
    }
}
