//! Fused-scorer / layered-path parity for the serving layer.
//!
//! The fused `serve::Scorer` (sketch → b-bit code → weight-slab gather
//! in one pass) must be a pure execution change: its decisions and
//! predictions must be **bit-identical** to the layered
//! `transform_codes → LinearOvR::{decisions_on, predict_on}` path on
//! dense and sparse inputs, at every thread count, every b-bit width,
//! fast math on or off, with a reused scratch arena or a fresh one per
//! row. The suite runs under both `MINMAX_THREADS=1` and `=4` in CI,
//! and pins explicit 1-vs-4-thread batches on top.

use minmax::cws::CwsHasher;
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::{Dataset, Dense, Matrix};
use minmax::features::Expansion;
use minmax::pipeline::Pipeline;
use minmax::serve::Scorer;
use minmax::sketch::Sketcher;
use minmax::svm::{LinearOvR, LinearSvmParams};

fn letter() -> Dataset {
    generate("letter", SynthConfig { seed: 13, n_train: 150, n_test: 100 }).unwrap()
}

/// Layered reference: the pipeline's own codes + per-row model scoring
/// (what `Pipeline::predict` computed before the fused path existed).
fn layered_labels(pipe: &Pipeline, x: &Matrix) -> Vec<i32> {
    let codes = pipe.transform_codes(x);
    let model = pipe.model().unwrap();
    (0..codes.rows()).map(|i| model.predict_on(&codes, i)).collect()
}

#[test]
fn scorer_matches_layered_path_across_bit_widths_and_threads() {
    let ds = letter();
    let sparse_test = Matrix::Sparse(ds.test_x.to_csr());
    for i_bits in [4u8, 8] {
        let mut pipe =
            Pipeline::builder().seed(11).samples(32).i_bits(i_bits).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        let want = layered_labels(&pipe, &ds.test_x);
        for threads in [1usize, 4] {
            assert_eq!(
                scorer.predict_batch_with_threads(&ds.test_x, threads),
                want,
                "b={i_bits} threads={threads} dense"
            );
            assert_eq!(
                scorer.predict_batch_with_threads(&sparse_test, threads),
                want,
                "b={i_bits} threads={threads} sparse"
            );
        }
        // Pipeline::predict itself rides the fused path now.
        assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
        assert_eq!(pipe.predict(&sparse_test).unwrap(), want);

        // Decisions — not just labels — are bit-identical.
        let codes = pipe.transform_codes(&ds.test_x);
        let model = pipe.model().unwrap();
        let dense = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; pipe.n_classes()];
        for i in 0..20 {
            scorer.score_dense_into(dense.row(i), &mut scratch, &mut got);
            let want_d = model.decisions_on(&codes, i);
            for (cls, (a, b)) in got.iter().zip(&want_d).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "b={i_bits} row {i} class {cls}");
            }
        }
    }
}

#[test]
fn scorer_matches_layered_path_at_16_bits() {
    // 16-bit codes explode the one-hot dimension (k · 65536 columns),
    // so pin parity on a binary problem with small k.
    let ds = letter();
    let y2: Vec<i32> = ds.train_y.iter().map(|&c| (c % 2 == 0) as i32).collect();
    let mut pipe = Pipeline::builder().seed(3).samples(4).i_bits(16).build().unwrap();
    pipe.fit(&ds.train_x, &y2).unwrap();
    let scorer = pipe.scorer(ds.dim()).unwrap();
    let want = layered_labels(&pipe, &ds.test_x);
    for threads in [1usize, 4] {
        assert_eq!(scorer.predict_batch_with_threads(&ds.test_x, threads), want);
    }
    assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
}

#[test]
fn fast_math_scorer_matches_fast_codes_path() {
    // With fast math ON, the fused scorer must equal the layered path
    // computed over the SAME fast-math sketches (the toggle changes the
    // sketch bits, and both paths must change together). The gate is
    // shared, so if the accuracy probe rejected fastmath both sides
    // fall back to exact identically.
    let ds = letter();
    let (k, i_bits, seed) = (24usize, 5u8, 9u64);
    let expansion = Expansion::new(k, i_bits);
    // Train on the exact-math codes (weights are arbitrary for parity).
    let hasher = CwsHasher::new(seed, k);
    let train_codes = expansion.encode(&hasher.sketch_matrix(&ds.train_x));
    let model =
        LinearOvR::train(&train_codes, &ds.train_y, ds.n_classes(), &LinearSvmParams::default());
    let scorer =
        Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap().with_fast_math(true);
    // Layered fast-math reference: fast engine sketches → encode →
    // predict_on.
    let fast_engine = minmax::cws::SketchEngine::new(seed, k, ds.dim()).with_fast_math(true);
    assert_eq!(scorer.fast_math(), fast_engine.fast_math());
    let dense = ds.test_x.to_dense();
    let rows: Vec<&[f32]> = (0..dense.rows()).map(|i| dense.row(i)).collect();
    let sketched: Vec<_> =
        fast_engine.sketch_rows(&rows).into_iter().map(Some).collect();
    let codes = expansion.encode(&sketched);
    let mut scratch = scorer.scratch();
    let mut got = vec![0.0f64; ds.n_classes()];
    for i in 0..dense.rows() {
        scorer.score_dense_into(dense.row(i), &mut scratch, &mut got);
        let want = model.decisions_on(&codes, i);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "fast-math row {i}");
        }
        assert_eq!(scorer.predict_dense(dense.row(i), &mut scratch), model.predict_on(&codes, i));
    }
    // And the exact-math scorer over the same weights differs only via
    // sketch bits: it must equal the exact layered path.
    let exact = Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap()
        .with_fast_math(false);
    let exact_codes = expansion.encode(&hasher.sketch_matrix(&ds.test_x));
    for i in 0..dense.rows() {
        assert_eq!(
            exact.predict_dense(dense.row(i), &mut scratch),
            model.predict_on(&exact_codes, i)
        );
    }
}

#[test]
fn exported_weights_scorer_agrees_with_model_scorer() {
    // A coordinator serving from the exported f32 [K, 2^bits, C] slab
    // (bias folded into slot 0) must predict exactly what the
    // full-precision from-model scorer predicts, and its decisions must
    // agree to f32 precision.
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(7).samples(16).i_bits(4).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let from_model = pipe.scorer(ds.dim()).unwrap();
    let exported = pipe.export_weights().unwrap();
    let from_export = Scorer::from_exported(
        pipe.sketcher().seed(),
        ds.dim(),
        *pipe.expansion(),
        pipe.n_classes(),
        &exported,
    )
    .unwrap()
    .with_fast_math(false);
    assert_eq!(
        from_model.predict_batch_with_threads(&ds.test_x, 1),
        from_export.predict_batch_with_threads(&ds.test_x, 1)
    );
    let dense = ds.test_x.to_dense();
    let mut sa = from_model.scratch();
    let mut sb = from_export.scratch();
    let (mut da, mut db) = (vec![0.0f64; pipe.n_classes()], vec![0.0f64; pipe.n_classes()]);
    for i in 0..dense.rows() {
        from_model.score_dense_into(dense.row(i), &mut sa, &mut da);
        from_export.score_dense_into(dense.row(i), &mut sb, &mut db);
        for (a, b) in da.iter().zip(&db) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "row {i}: {a} vs {b}");
        }
    }
}

#[test]
fn scratch_reuse_equals_fresh_scratch() {
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(5).samples(32).i_bits(6).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let scorer = pipe.scorer(ds.dim()).unwrap();
    let dense = ds.test_x.to_dense();
    let csr = ds.test_x.to_csr();
    let mut shared = scorer.scratch();
    let (mut a, mut b) = (vec![0.0f64; pipe.n_classes()], vec![0.0f64; pipe.n_classes()]);
    for i in 0..dense.rows() {
        // Alternate dense/sparse through ONE scratch to shake out any
        // state leakage between representations and rows.
        scorer.score_dense_into(dense.row(i), &mut shared, &mut a);
        let mut fresh = scorer.scratch();
        scorer.score_dense_into(dense.row(i), &mut fresh, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "dense row {i}");
        scorer.score_sparse_into(csr.row(i), &mut shared, &mut a);
        let mut fresh = scorer.scratch();
        scorer.score_sparse_into(csr.row(i), &mut fresh, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "sparse row {i}");
    }
}

#[test]
fn empty_rows_agree_with_layered_path() {
    // A serving batch with all-zero rows in the middle: the fused path
    // must reproduce the layered path's bias-only scoring for them.
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(2).samples(16).i_bits(4).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let dense = ds.test_x.to_dense();
    let dim = ds.dim();
    let zero = vec![0.0f32; dim];
    let mut rows: Vec<&[f32]> = Vec::new();
    for i in 0..10 {
        rows.push(dense.row(i));
        rows.push(&zero);
    }
    let mixed = Matrix::Dense(Dense::from_rows(&rows));
    let scorer = pipe.scorer(dim).unwrap();
    let want = layered_labels(&pipe, &mixed);
    for threads in [1usize, 4] {
        assert_eq!(scorer.predict_batch_with_threads(&mixed, threads), want);
    }
    assert_eq!(pipe.predict(&mixed).unwrap(), want);
    // Sparse twin (empty CSR rows).
    let mixed_sparse = Matrix::Sparse(mixed.to_csr());
    assert_eq!(scorer.predict_batch(&mixed_sparse), want);
}

#[test]
fn precision_packing_matrix_pins_parity() {
    // The PR 7 matrix: {f64, f32, int8} slabs × {plain, packed codes}
    // × {1, 4} threads × b-bit widths {4, 8, 16}. Contracts pinned:
    // packing and thread count NEVER change bits; f64 is bit-identical
    // to the PR 5 baseline scorer; f32 decisions track f64 to rounding
    // (and labels agree on this data); int8 is tolerance-gated (label
    // agreement — the fine-grained k·scale/2 decision bound is pinned
    // by the serve module's unit tests, which can see the scale).
    use minmax::serve::SlabPrecision;
    let ds = letter();
    let y2: Vec<i32> = ds.train_y.iter().map(|&c| (c % 2 == 0) as i32).collect();
    let configs: [(u8, usize, &[i32]); 3] =
        [(4, 16, &ds.train_y), (8, 8, &ds.train_y), (16, 4, &y2)];
    let dense = ds.test_x.to_dense();
    for (i_bits, k, train_y) in configs {
        let mut pipe = Pipeline::builder().seed(19).samples(k).i_bits(i_bits).build().unwrap();
        pipe.fit(&ds.train_x, train_y).unwrap();
        let base = pipe.scorer(ds.dim()).unwrap();
        let baseline = base.predict_batch_with_threads(&ds.test_x, 1);
        let mut base_scratch = base.scratch();
        for precision in [SlabPrecision::F64, SlabPrecision::F32, SlabPrecision::Int8] {
            let plain = base.clone().with_precision(precision);
            assert_eq!(plain.precision(), precision, "b={i_bits}: {precision} must engage");
            let packed = plain.clone().with_packed_codes(true);
            assert!(packed.packed_codes(), "b={i_bits} codes must pack");
            let plain_labels = plain.predict_batch_with_threads(&ds.test_x, 1);
            for (variant, name) in [(&plain, "plain"), (&packed, "packed")] {
                for threads in [1usize, 4] {
                    assert_eq!(
                        variant.predict_batch_with_threads(&ds.test_x, threads),
                        plain_labels,
                        "b={i_bits} {precision} {name} threads={threads}"
                    );
                }
            }
            // Packed decisions are bit-identical to plain, and the
            // precision tolerance holds against the f64 baseline.
            let mut sp = plain.scratch();
            let mut sk = packed.scratch();
            let c = pipe.n_classes();
            let (mut dp, mut dk, mut db) = (vec![0.0; c], vec![0.0; c], vec![0.0; c]);
            for i in 0..dense.rows().min(20) {
                plain.score_dense_into(dense.row(i), &mut sp, &mut dp);
                packed.score_dense_into(dense.row(i), &mut sk, &mut dk);
                for (a, b) in dp.iter().zip(&dk) {
                    assert_eq!(a.to_bits(), b.to_bits(), "b={i_bits} {precision} row {i}");
                }
                base.score_dense_into(dense.row(i), &mut base_scratch, &mut db);
                match precision {
                    SlabPrecision::F64 => {
                        for (a, b) in dp.iter().zip(&db) {
                            assert_eq!(a.to_bits(), b.to_bits(), "f64 must stay exact, row {i}");
                        }
                    }
                    SlabPrecision::F32 => {
                        for (a, b) in dp.iter().zip(&db) {
                            assert!(
                                (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                                "f32 row {i}: {a} vs {b}"
                            );
                        }
                    }
                    SlabPrecision::Int8 => {}
                }
            }
            match precision {
                SlabPrecision::F64 | SlabPrecision::F32 => {
                    assert_eq!(plain_labels, baseline, "b={i_bits} {precision} labels");
                }
                SlabPrecision::Int8 => {
                    let agree = plain_labels.iter().zip(&baseline).filter(|(a, b)| a == b).count();
                    assert!(
                        agree * 10 >= baseline.len() * 9,
                        "b={i_bits} int8 agreement {agree}/{}",
                        baseline.len()
                    );
                }
            }
        }
    }
}

#[test]
fn exported_slabs_roundtrip_at_every_precision() {
    // Pipeline::export_weights_with → Scorer::from_exported_slab for
    // all three precisions: the deployment path a coordinator uses when
    // it only holds exported bytes.
    use minmax::serve::{ExportedWeights, SlabPrecision};
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(7).samples(16).i_bits(4).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let seed = pipe.sketcher().seed();
    let expansion = *pipe.expansion();
    let c = pipe.n_classes();
    let from_model = pipe.scorer(ds.dim()).unwrap();

    let build = |w: &ExportedWeights| {
        Scorer::from_exported_slab(seed, ds.dim(), expansion, c, w)
            .unwrap()
            .with_fast_math(false)
    };
    let f64_scorer = build(&pipe.export_weights_with(SlabPrecision::F64).unwrap());
    let f32_scorer = build(&pipe.export_weights_with(SlabPrecision::F32).unwrap());
    let int8_scorer = build(&pipe.export_weights_with(SlabPrecision::Int8).unwrap());
    assert_eq!(f64_scorer.precision(), SlabPrecision::F64);
    assert_eq!(f32_scorer.precision(), SlabPrecision::F32);
    assert_eq!(int8_scorer.precision(), SlabPrecision::Int8);

    // The f64 slab differs from the from-model scorer only in where the
    // bias enters (folded into slot 0 vs added after the gather), so
    // decisions agree to f64 rounding and labels match; f32 matches the
    // legacy from_exported entry bit-for-bit; int8 stays close enough
    // to agree on almost every label.
    let legacy = Scorer::from_exported(
        seed,
        ds.dim(),
        expansion,
        c,
        match &pipe.export_weights_with(SlabPrecision::F32).unwrap() {
            ExportedWeights::F32(w) => w,
            _ => unreachable!(),
        },
    )
    .unwrap()
    .with_fast_math(false);
    let want = from_model.predict_batch_with_threads(&ds.test_x, 1);
    assert_eq!(f64_scorer.predict_batch_with_threads(&ds.test_x, 1), want);
    assert_eq!(
        f32_scorer.predict_batch_with_threads(&ds.test_x, 1),
        legacy.predict_batch_with_threads(&ds.test_x, 1)
    );
    let int8_labels = int8_scorer.predict_batch_with_threads(&ds.test_x, 1);
    let agree = int8_labels.iter().zip(&want).filter(|(a, b)| a == b).count();
    assert!(agree * 10 >= want.len() * 9, "int8 export agreement {agree}/{}", want.len());

    let dense = ds.test_x.to_dense();
    let mut sm = from_model.scratch();
    let mut s64 = f64_scorer.scratch();
    let (mut a, mut b) = (vec![0.0f64; c], vec![0.0f64; c]);
    for i in 0..dense.rows().min(20) {
        if dense.row(i).iter().all(|&v| v <= 0.0) {
            continue; // empty rows miss the slot-0 bias fold by design
        }
        from_model.score_dense_into(dense.row(i), &mut sm, &mut a);
        f64_scorer.score_dense_into(dense.row(i), &mut s64, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-9 * (1.0 + x.abs()), "row {i}: {x} vs {y}");
        }
    }
}

#[test]
fn packed_codes_roundtrip_through_the_public_api() {
    // CodeMatrix → PackedCodes → CodeMatrix is lossless for word-
    // aligned widths (the finer-grained property test lives in
    // features::codes; this pins the public surface).
    let ds = letter();
    for i_bits in [4u8, 8] {
        let mut pipe = Pipeline::builder().seed(23).samples(12).i_bits(i_bits).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let codes = pipe.transform_codes(&ds.test_x);
        let packed = codes.pack().expect("word-aligned width must pack");
        assert_eq!(packed.bits(), i_bits);
        assert_eq!(packed.rows(), codes.rows());
        assert_eq!(packed.to_code_matrix(), codes, "b={i_bits}");
    }
}

#[test]
fn scaled_pipelines_ride_the_scorer_bit_identically() {
    use minmax::pipeline::Scaling;
    let ds = letter();
    let sparse_test = Matrix::Sparse(ds.test_x.to_csr());
    for scaling in [Scaling::L1, Scaling::L2, Scaling::Binarize] {
        let mut pipe = Pipeline::builder()
            .seed(17)
            .samples(16)
            .i_bits(4)
            .scaling(scaling)
            .build()
            .unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        assert_eq!(scorer.scaling(), scaling);
        let want = layered_labels(&pipe, &ds.test_x);
        assert_eq!(scorer.predict_batch(&ds.test_x), want, "{scaling:?} dense");
        let want_sparse = layered_labels(&pipe, &sparse_test);
        assert_eq!(scorer.predict_batch(&sparse_test), want_sparse, "{scaling:?} sparse");
        assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
    }
}
