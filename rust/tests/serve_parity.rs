//! Fused-scorer / layered-path parity for the serving layer.
//!
//! The fused `serve::Scorer` (sketch → b-bit code → weight-slab gather
//! in one pass) must be a pure execution change: its decisions and
//! predictions must be **bit-identical** to the layered
//! `transform_codes → LinearOvR::{decisions_on, predict_on}` path on
//! dense and sparse inputs, at every thread count, every b-bit width,
//! fast math on or off, with a reused scratch arena or a fresh one per
//! row. The suite runs under both `MINMAX_THREADS=1` and `=4` in CI,
//! and pins explicit 1-vs-4-thread batches on top.

use minmax::cws::CwsHasher;
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::{Dataset, Dense, Matrix};
use minmax::features::Expansion;
use minmax::pipeline::Pipeline;
use minmax::serve::Scorer;
use minmax::sketch::Sketcher;
use minmax::svm::{LinearOvR, LinearSvmParams};

fn letter() -> Dataset {
    generate("letter", SynthConfig { seed: 13, n_train: 150, n_test: 100 }).unwrap()
}

/// Layered reference: the pipeline's own codes + per-row model scoring
/// (what `Pipeline::predict` computed before the fused path existed).
fn layered_labels(pipe: &Pipeline, x: &Matrix) -> Vec<i32> {
    let codes = pipe.transform_codes(x);
    let model = pipe.model().unwrap();
    (0..codes.rows()).map(|i| model.predict_on(&codes, i)).collect()
}

#[test]
fn scorer_matches_layered_path_across_bit_widths_and_threads() {
    let ds = letter();
    let sparse_test = Matrix::Sparse(ds.test_x.to_csr());
    for i_bits in [4u8, 8] {
        let mut pipe =
            Pipeline::builder().seed(11).samples(32).i_bits(i_bits).build().unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        let want = layered_labels(&pipe, &ds.test_x);
        for threads in [1usize, 4] {
            assert_eq!(
                scorer.predict_batch_with_threads(&ds.test_x, threads),
                want,
                "b={i_bits} threads={threads} dense"
            );
            assert_eq!(
                scorer.predict_batch_with_threads(&sparse_test, threads),
                want,
                "b={i_bits} threads={threads} sparse"
            );
        }
        // Pipeline::predict itself rides the fused path now.
        assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
        assert_eq!(pipe.predict(&sparse_test).unwrap(), want);

        // Decisions — not just labels — are bit-identical.
        let codes = pipe.transform_codes(&ds.test_x);
        let model = pipe.model().unwrap();
        let dense = ds.test_x.to_dense();
        let mut scratch = scorer.scratch();
        let mut got = vec![0.0f64; pipe.n_classes()];
        for i in 0..20 {
            scorer.score_dense_into(dense.row(i), &mut scratch, &mut got);
            let want_d = model.decisions_on(&codes, i);
            for (cls, (a, b)) in got.iter().zip(&want_d).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "b={i_bits} row {i} class {cls}");
            }
        }
    }
}

#[test]
fn scorer_matches_layered_path_at_16_bits() {
    // 16-bit codes explode the one-hot dimension (k · 65536 columns),
    // so pin parity on a binary problem with small k.
    let ds = letter();
    let y2: Vec<i32> = ds.train_y.iter().map(|&c| (c % 2 == 0) as i32).collect();
    let mut pipe = Pipeline::builder().seed(3).samples(4).i_bits(16).build().unwrap();
    pipe.fit(&ds.train_x, &y2).unwrap();
    let scorer = pipe.scorer(ds.dim()).unwrap();
    let want = layered_labels(&pipe, &ds.test_x);
    for threads in [1usize, 4] {
        assert_eq!(scorer.predict_batch_with_threads(&ds.test_x, threads), want);
    }
    assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
}

#[test]
fn fast_math_scorer_matches_fast_codes_path() {
    // With fast math ON, the fused scorer must equal the layered path
    // computed over the SAME fast-math sketches (the toggle changes the
    // sketch bits, and both paths must change together). The gate is
    // shared, so if the accuracy probe rejected fastmath both sides
    // fall back to exact identically.
    let ds = letter();
    let (k, i_bits, seed) = (24usize, 5u8, 9u64);
    let expansion = Expansion::new(k, i_bits);
    // Train on the exact-math codes (weights are arbitrary for parity).
    let hasher = CwsHasher::new(seed, k);
    let train_codes = expansion.encode(&hasher.sketch_matrix(&ds.train_x));
    let model =
        LinearOvR::train(&train_codes, &ds.train_y, ds.n_classes(), &LinearSvmParams::default());
    let scorer =
        Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap().with_fast_math(true);
    // Layered fast-math reference: fast engine sketches → encode →
    // predict_on.
    let fast_engine = minmax::cws::SketchEngine::new(seed, k, ds.dim()).with_fast_math(true);
    assert_eq!(scorer.fast_math(), fast_engine.fast_math());
    let dense = ds.test_x.to_dense();
    let rows: Vec<&[f32]> = (0..dense.rows()).map(|i| dense.row(i)).collect();
    let sketched: Vec<_> =
        fast_engine.sketch_rows(&rows).into_iter().map(Some).collect();
    let codes = expansion.encode(&sketched);
    let mut scratch = scorer.scratch();
    let mut got = vec![0.0f64; ds.n_classes()];
    for i in 0..dense.rows() {
        scorer.score_dense_into(dense.row(i), &mut scratch, &mut got);
        let want = model.decisions_on(&codes, i);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "fast-math row {i}");
        }
        assert_eq!(scorer.predict_dense(dense.row(i), &mut scratch), model.predict_on(&codes, i));
    }
    // And the exact-math scorer over the same weights differs only via
    // sketch bits: it must equal the exact layered path.
    let exact = Scorer::from_model(seed, ds.dim(), expansion, &model).unwrap()
        .with_fast_math(false);
    let exact_codes = expansion.encode(&hasher.sketch_matrix(&ds.test_x));
    for i in 0..dense.rows() {
        assert_eq!(
            exact.predict_dense(dense.row(i), &mut scratch),
            model.predict_on(&exact_codes, i)
        );
    }
}

#[test]
fn exported_weights_scorer_agrees_with_model_scorer() {
    // A coordinator serving from the exported f32 [K, 2^bits, C] slab
    // (bias folded into slot 0) must predict exactly what the
    // full-precision from-model scorer predicts, and its decisions must
    // agree to f32 precision.
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(7).samples(16).i_bits(4).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let from_model = pipe.scorer(ds.dim()).unwrap();
    let exported = pipe.export_weights().unwrap();
    let from_export = Scorer::from_exported(
        pipe.sketcher().seed(),
        ds.dim(),
        *pipe.expansion(),
        pipe.n_classes(),
        &exported,
    )
    .unwrap()
    .with_fast_math(false);
    assert_eq!(
        from_model.predict_batch_with_threads(&ds.test_x, 1),
        from_export.predict_batch_with_threads(&ds.test_x, 1)
    );
    let dense = ds.test_x.to_dense();
    let mut sa = from_model.scratch();
    let mut sb = from_export.scratch();
    let (mut da, mut db) = (vec![0.0f64; pipe.n_classes()], vec![0.0f64; pipe.n_classes()]);
    for i in 0..dense.rows() {
        from_model.score_dense_into(dense.row(i), &mut sa, &mut da);
        from_export.score_dense_into(dense.row(i), &mut sb, &mut db);
        for (a, b) in da.iter().zip(&db) {
            assert!((a - b).abs() < 1e-4 * (1.0 + a.abs()), "row {i}: {a} vs {b}");
        }
    }
}

#[test]
fn scratch_reuse_equals_fresh_scratch() {
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(5).samples(32).i_bits(6).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let scorer = pipe.scorer(ds.dim()).unwrap();
    let dense = ds.test_x.to_dense();
    let csr = ds.test_x.to_csr();
    let mut shared = scorer.scratch();
    let (mut a, mut b) = (vec![0.0f64; pipe.n_classes()], vec![0.0f64; pipe.n_classes()]);
    for i in 0..dense.rows() {
        // Alternate dense/sparse through ONE scratch to shake out any
        // state leakage between representations and rows.
        scorer.score_dense_into(dense.row(i), &mut shared, &mut a);
        let mut fresh = scorer.scratch();
        scorer.score_dense_into(dense.row(i), &mut fresh, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "dense row {i}");
        scorer.score_sparse_into(csr.row(i), &mut shared, &mut a);
        let mut fresh = scorer.scratch();
        scorer.score_sparse_into(csr.row(i), &mut fresh, &mut b);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "sparse row {i}");
    }
}

#[test]
fn empty_rows_agree_with_layered_path() {
    // A serving batch with all-zero rows in the middle: the fused path
    // must reproduce the layered path's bias-only scoring for them.
    let ds = letter();
    let mut pipe = Pipeline::builder().seed(2).samples(16).i_bits(4).build().unwrap();
    pipe.fit(&ds.train_x, &ds.train_y).unwrap();
    let dense = ds.test_x.to_dense();
    let dim = ds.dim();
    let zero = vec![0.0f32; dim];
    let mut rows: Vec<&[f32]> = Vec::new();
    for i in 0..10 {
        rows.push(dense.row(i));
        rows.push(&zero);
    }
    let mixed = Matrix::Dense(Dense::from_rows(&rows));
    let scorer = pipe.scorer(dim).unwrap();
    let want = layered_labels(&pipe, &mixed);
    for threads in [1usize, 4] {
        assert_eq!(scorer.predict_batch_with_threads(&mixed, threads), want);
    }
    assert_eq!(pipe.predict(&mixed).unwrap(), want);
    // Sparse twin (empty CSR rows).
    let mixed_sparse = Matrix::Sparse(mixed.to_csr());
    assert_eq!(scorer.predict_batch(&mixed_sparse), want);
}

#[test]
fn scaled_pipelines_ride_the_scorer_bit_identically() {
    use minmax::pipeline::Scaling;
    let ds = letter();
    let sparse_test = Matrix::Sparse(ds.test_x.to_csr());
    for scaling in [Scaling::L1, Scaling::L2, Scaling::Binarize] {
        let mut pipe = Pipeline::builder()
            .seed(17)
            .samples(16)
            .i_bits(4)
            .scaling(scaling)
            .build()
            .unwrap();
        pipe.fit(&ds.train_x, &ds.train_y).unwrap();
        let scorer = pipe.scorer(ds.dim()).unwrap();
        assert_eq!(scorer.scaling(), scaling);
        let want = layered_labels(&pipe, &ds.test_x);
        assert_eq!(scorer.predict_batch(&ds.test_x), want, "{scaling:?} dense");
        let want_sparse = layered_labels(&pipe, &sparse_test);
        assert_eq!(scorer.predict_batch(&sparse_test), want_sparse, "{scaling:?} sparse");
        assert_eq!(pipe.predict(&ds.test_x).unwrap(), want);
    }
}
