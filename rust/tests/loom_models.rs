//! Model checks over the coordinator's *actual* concurrency
//! primitives — the shard-queue/steal/swap machinery in
//! `coordinator::queue` and the counter-ordering contract in
//! `coordinator::metrics` — not re-implementations of them.
//!
//! One body, two build modes:
//!
//! * **loom** — the CI `loom` job appends the loom dev-dependency to
//!   `rust/Cargo.toml` (see the comment there) and builds with
//!   `RUSTFLAGS="--cfg loom"`. The `util::sync` facade then resolves
//!   to loom's instrumented primitives and [`model`] is `loom::model`:
//!   every scenario is explored over **all** interleavings of its 2–3
//!   threads (bounded by `LOOM_MAX_PREEMPTIONS` in CI).
//! * **default** — no loom dependency anywhere; [`model`] runs the
//!   same closure once on real threads. That keeps the scenarios
//!   compiled, linted, and passing as a deterministic smoke test under
//!   plain `cargo test -q` (tier-1).
//!
//! Scenario rule: every queue is closed before a scenario ends — loom
//! flags a thread still parked on a Condvar at execution end as a
//! deadlock, and the production shutdown protocol closes queues anyway.
//!
//! The invariants pinned here are catalogued in DESIGN.md §2.8.

use minmax::coordinator::metrics::Metrics;
use minmax::coordinator::queue::{
    steal, steal_any, Pop, PushError, ShardQueue, SwapCell, STEAL_POLL,
};
use minmax::util::sync::{thread, Arc};

/// Exhaustive interleaving exploration under `--cfg loom`; a single
/// real-thread execution otherwise.
#[cfg(loom)]
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    loom::model(f);
}

#[cfg(not(loom))]
fn model<F: Fn() + Sync + Send + 'static>(f: F) {
    f();
}

/// The worker half of `cluster::worker_loop`, reduced to its queue
/// discipline: serve own shard, steal from siblings when idle, and on
/// close run the `steal_any` shutdown sweep so no accepted request is
/// stranded in a sibling's queue.
fn drain_worker(me: usize, qs: &[ShardQueue<u64>]) -> Vec<u64> {
    let mut got = Vec::new();
    loop {
        match qs[me].pop_wait(STEAL_POLL) {
            Pop::Req(r) => got.push(*r),
            Pop::Empty => {
                if let Some(r) = steal(me, qs) {
                    got.push(*r);
                }
            }
            Pop::Closed => break,
        }
    }
    while let Some(r) = steal_any(me, qs) {
        got.push(*r);
    }
    got
}

/// Invariant: per-shard FIFO with nothing lost or duplicated across a
/// concurrent close — `Pop::Closed` is only reported after every
/// accepted request has been handed out.
#[test]
fn queue_fifo_no_loss_through_close() {
    model(|| {
        let q: Arc<ShardQueue<u64>> = Arc::new(ShardQueue::new());
        let producer = {
            let q = Arc::clone(&q);
            thread::spawn(move || {
                q.push(1, 4, None).unwrap();
                q.push(2, 4, None).unwrap();
                q.close();
            })
        };
        let mut got = Vec::new();
        loop {
            match q.pop_wait(STEAL_POLL) {
                Pop::Req(r) => got.push(*r),
                Pop::Empty => {}
                Pop::Closed => break,
            }
        }
        producer.join().unwrap();
        assert_eq!(got, [1, 2], "FIFO order, nothing lost or duplicated");
        // A post-close submit is a typed rejection with the request
        // handed back, never a silent drop.
        let q2: ShardQueue<u64> = ShardQueue::new();
        q2.close();
        assert_eq!(q2.push(9, 4, None).unwrap_err(), (PushError::Closed, 9));
    });
}

/// Invariant: with two racing submitters over a watermark of 1,
/// exactly one lands and exactly one is shed with the depth it
/// observed — accept and shed are mutually exclusive per submit, and
/// the shed request is handed back intact for fail-over.
#[test]
fn watermark_sheds_exactly_one_of_two() {
    model(|| {
        let q: Arc<ShardQueue<u64>> = Arc::new(ShardQueue::new());
        let submit = |v: u64| {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(v, 2, Some(1)))
        };
        let (t1, t2) = (submit(10), submit(20));
        let mut handed_back = Vec::new();
        for r in [t1.join().unwrap(), t2.join().unwrap()] {
            if let Err((e, req)) = r {
                assert_eq!(e, PushError::Shed { depth: 1, watermark: 1 });
                handed_back.push(req);
            }
        }
        q.close();
        let served = match q.pop_wait(STEAL_POLL) {
            Pop::Req(r) => *r,
            _ => panic!("the accepted request must be queued"),
        };
        assert!(matches!(q.pop_wait(STEAL_POLL), Pop::Closed));
        assert_eq!(handed_back.len(), 1, "exactly one of two submits is shed");
        assert_ne!(served, handed_back[0], "the shed request is not also served");
    });
}

/// Invariant: the hard cap (no watermark) rejects with
/// `PushError::Full` instead of `Shed`, again exactly once when two
/// submitters race over a single free slot.
#[test]
fn hard_cap_rejects_exactly_one_of_two() {
    model(|| {
        let q: Arc<ShardQueue<u64>> = Arc::new(ShardQueue::new());
        let submit = |v: u64| {
            let q = Arc::clone(&q);
            thread::spawn(move || q.push(v, 1, None))
        };
        let (t1, t2) = (submit(10), submit(20));
        let mut handed_back = Vec::new();
        for r in [t1.join().unwrap(), t2.join().unwrap()] {
            if let Err((e, req)) = r {
                assert_eq!(e, PushError::Full, "cap overflow is backpressure, not shedding");
                handed_back.push(req);
            }
        }
        q.close();
        let served = match q.pop_wait(STEAL_POLL) {
            Pop::Req(r) => *r,
            _ => panic!("the accepted request must be queued"),
        };
        assert!(matches!(q.pop_wait(STEAL_POLL), Pop::Closed));
        assert_eq!(handed_back.len(), 1, "exactly one of two submits bounces");
        assert_ne!(served, handed_back[0]);
    });
}

/// Invariant: hot swap. Readers racing a publisher only ever see
/// fully-initialized `(version, payload)` pairs at monotonically
/// non-decreasing versions, and an in-flight holder's `Arc` survives
/// both swaps untouched (the drain half of the publish protocol).
#[test]
fn swap_cell_monotone_and_inflight_arc_survives() {
    model(|| {
        let cell = Arc::new(SwapCell::new((1u64, 10u64)));
        let held = cell.get();
        let publisher = {
            let c = Arc::clone(&cell);
            thread::spawn(move || {
                for _ in 0..2 {
                    c.update(|cur| {
                        let v = cur.0 + 1;
                        ((v, v * 10), v)
                    });
                }
            })
        };
        let reader = {
            let c = Arc::clone(&cell);
            thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2 {
                    let cur = c.get();
                    assert_eq!(cur.1, cur.0 * 10, "never a half-published pair");
                    assert!(cur.0 >= last, "versions are monotone per reader");
                    last = cur.0;
                }
            })
        };
        publisher.join().unwrap();
        reader.join().unwrap();
        assert_eq!(*held, (1, 10), "in-flight holder keeps its Arc across swaps");
        assert_eq!(cell.get().0, 3, "both publishes landed, in order");
    });
}

/// Invariant: shutdown drain. Two workers race over two shard queues
/// (own-pop, sibling steal, then the close-triggered `steal_any`
/// sweep) while the submitter pushes and closes — every accepted
/// request is served by exactly one worker, none twice, none stranded.
#[test]
fn shutdown_drain_serves_every_request_exactly_once() {
    model(|| {
        let qs: Arc<Vec<ShardQueue<u64>>> =
            Arc::new((0..2).map(|_| ShardQueue::new()).collect());
        qs[0].push(1, 8, None).unwrap();
        let workers: Vec<_> = (0..2)
            .map(|me| {
                let qs = Arc::clone(&qs);
                thread::spawn(move || drain_worker(me, &qs))
            })
            .collect();
        qs[1].push(2, 8, None).unwrap();
        qs[0].push(3, 8, None).unwrap();
        for q in qs.iter() {
            q.close();
        }
        let mut got: Vec<u64> = Vec::new();
        for w in workers {
            got.extend(w.join().unwrap());
        }
        got.sort_unstable();
        assert_eq!(got, [1, 2, 3], "each accepted request served exactly once");
    });
}

/// Invariant: supervisor respawn handoff (DESIGN.md §2.9). A
/// first-incarnation worker serves at most one request and dies; the
/// supervisor joins the corpse and only then spawns the replacement
/// over the same queues — the production `supervisor_loop` handoff.
/// Across the death, every accepted request is served by exactly one
/// incarnation: none lost with the corpse (its last pop was answered
/// before dying — deaths never hold a request), none served twice.
#[test]
fn respawn_handoff_serves_every_request_exactly_once() {
    model(|| {
        let qs: Arc<Vec<ShardQueue<u64>>> =
            Arc::new((0..1).map(|_| ShardQueue::new()).collect());
        qs[0].push(1, 8, None).unwrap();
        qs[0].push(2, 8, None).unwrap();
        // Incarnation 0: serve one request, then die.
        let w0 = {
            let qs = Arc::clone(&qs);
            thread::spawn(move || loop {
                match qs[0].pop_wait(STEAL_POLL) {
                    Pop::Req(r) => return vec![*r],
                    Pop::Empty => {}
                    Pop::Closed => return Vec::new(),
                }
            })
        };
        // Supervisor: join the corpse first (its served request is
        // final), then hand the queues to incarnation 1.
        let supervisor = {
            let qs = Arc::clone(&qs);
            thread::spawn(move || {
                let mut got = w0.join().unwrap();
                let w1 = {
                    let qs = Arc::clone(&qs);
                    thread::spawn(move || drain_worker(0, &qs))
                };
                got.extend(w1.join().unwrap());
                got
            })
        };
        qs[0].close();
        let mut got = supervisor.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, [1, 2], "nothing lost with the corpse, nothing served twice");
    });
}

/// Invariant: the metrics read-order contract. Outcome counters are
/// Release-incremented after their request increment and snapshot
/// loads them Acquire *before* the request counter, so a concurrent
/// snapshot can never report `completed + rejected + shed > requests`
/// — the torn-total bug the `service.rs` `stopping`-flag audit
/// (ISSUE 9) is a cousin of.
#[test]
fn metrics_snapshot_never_tears() {
    model(|| {
        let m = Arc::new(Metrics::new());
        let w1 = {
            let m = Arc::clone(&m);
            thread::spawn(move || {
                m.record_request();
                m.record_latency_ms(0.5);
            })
        };
        let w2 = {
            let m = Arc::clone(&m);
            thread::spawn(move || {
                m.record_request();
                m.record_rejected();
                m.record_request();
                m.record_shed();
            })
        };
        let s = m.snapshot();
        assert!(
            s.completed + s.rejected + s.shed <= s.requests,
            "torn snapshot: {} + {} + {} > {}",
            s.completed,
            s.rejected,
            s.shed,
            s.requests
        );
        w1.join().unwrap();
        w2.join().unwrap();
        let s = m.snapshot();
        assert_eq!((s.requests, s.completed, s.rejected, s.shed), (3, 1, 1, 1));
        assert_eq!(s.latency_hist.iter().sum::<u64>(), s.completed);
    });
}
