//! Precomputed / on-the-fly Gram parity for the kernel-SVM layer.
//!
//! The `GramSource` abstraction must be a pure representation change:
//! the solver sees bit-identical kernel rows whether the Gram is
//! materialized up front (`Dense` / `Precomputed`) or streamed on
//! demand (`OnTheFly` — any cache size, any fill thread count), so
//! binary `KernelModel`s and `KernelOvO` predictions must be
//! **bit-identical** across sources. Shrinking is a separate throughput
//! knob: on/off reach the same dual objective within the convergence
//! tolerance (not the same bits). The suite runs under both
//! `MINMAX_THREADS=1` and `=4` in CI, covering the env-driven default
//! paths on top of the explicit thread counts pinned here.

use minmax::data::dense::Dense;
use minmax::data::sparse::Csr;
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::Matrix;
use minmax::kernels::gram::{GramSource, OnTheFly, Precomputed};
use minmax::kernels::matrix::{kernel_matrix, kernel_matrix_sym};
use minmax::kernels::KernelKind;
use minmax::svm::kernel::{dual_objective, train_binary, train_binary_on};
use minmax::svm::{KernelOvO, KernelSvmParams};
use minmax::util::rng::Pcg64;

/// The ring problem of the solver's own tests: linearly inseparable,
/// min-max-kernel separable — the acceptance workload.
fn ring_data(n: usize, seed: u64) -> (Dense, Vec<i32>) {
    let mut rng = Pcg64::new(seed);
    let mut x = Dense::zeros(n, 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = if i % 2 == 0 { 1 } else { -1 };
        let radius = if label == 1 { 0.5 } else { 1.5 };
        let th = rng.uniform() * std::f64::consts::TAU;
        x.set(i, 0, (2.0 + radius * th.cos() + 0.05 * rng.normal()) as f32);
        x.set(i, 1, (2.0 + radius * th.sin() + 0.05 * rng.normal()) as f32);
        y.push(label);
    }
    (x, y)
}

fn assert_models_bit_identical(a: &minmax::svm::KernelModel, b: &minmax::svm::KernelModel) {
    assert_eq!(a.epochs_run, b.epochs_run, "epoch counts differ");
    assert_eq!(a.coef.len(), b.coef.len());
    for (i, (x, y)) in a.coef.iter().zip(&b.coef).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "coef[{i}] differs: {x} vs {y}");
    }
}

#[test]
fn on_the_fly_trains_bit_identical_models() {
    let n = 120;
    let (x, y) = ring_data(n, 1);
    let m = Matrix::Dense(x);
    let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
    for shrink in [true, false] {
        let p = KernelSvmParams { c: 32.0, shrink, ..Default::default() };
        let base = train_binary(&pre, &y, &p);
        // Any cache size (0 = pure streaming, n/4 = the acceptance cap,
        // n = everything resident) × any fill thread count.
        for cache in [0usize, 1, n / 4, n] {
            for threads in [1usize, 4] {
                let otf = OnTheFly::new(KernelKind::MinMax, &m)
                    .with_cache_rows(cache)
                    .with_threads(threads);
                let model = train_binary_on(&otf, &y, &p);
                assert_models_bit_identical(&base, &model);
                assert!(
                    otf.cached_rows() <= cache,
                    "cache overflow: {} resident > cap {cache}",
                    otf.cached_rows()
                );
            }
        }
    }
}

#[test]
fn on_the_fly_parity_holds_on_sparse_matrices() {
    let (x, y) = ring_data(90, 2);
    let m = Matrix::Sparse(Csr::from_dense(&x));
    let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
    let p = KernelSvmParams { c: 8.0, ..Default::default() };
    let base = train_binary(&pre, &y, &p);
    let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(10);
    assert_models_bit_identical(&base, &train_binary_on(&otf, &y, &p));
}

#[test]
fn precomputed_wrapper_matches_dense() {
    let (x, y) = ring_data(60, 3);
    let m = Matrix::Dense(x);
    let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
    let p = KernelSvmParams::default();
    let a = train_binary(&pre, &y, &p);
    let b = train_binary_on(&Precomputed(pre), &y, &p);
    assert_models_bit_identical(&a, &b);
}

#[test]
fn ovo_predictions_identical_across_gram_sources() {
    // Multiclass: every pair trains against a lazy SubsetGram view of
    // the shared source; predictions must agree bit-for-bit between the
    // precomputed Gram and a tightly-cached on-the-fly source at any
    // pair-level thread count.
    let ds = generate("vowel", SynthConfig { seed: 7, n_train: 90, n_test: 45 }).unwrap();
    let n_classes = ds.n_classes();
    let p = KernelSvmParams::default();
    let pre = kernel_matrix_sym(KernelKind::MinMax, &ds.train_x);
    let k_test = kernel_matrix(KernelKind::MinMax, &ds.test_x, &ds.train_x);
    let base = KernelOvO::train(&pre, &ds.train_y, n_classes, &p);
    let otf = OnTheFly::new(KernelKind::MinMax, &ds.train_x).with_cache_rows(90 / 4);
    for threads in [1usize, 4] {
        let model = KernelOvO::train_with_threads(&otf, &ds.train_y, n_classes, &p, threads);
        assert_eq!(base.n_models(), model.n_models());
        for i in 0..k_test.rows() {
            assert_eq!(
                base.predict(k_test.row(i)),
                model.predict(k_test.row(i)),
                "prediction differs at test row {i} (threads={threads})"
            );
        }
    }
    // The shared cache was actually exercised across pairs.
    let stats = otf.stats();
    assert!(stats.rows_computed > 0);
    assert!(otf.cached_rows() <= 90 / 4);
}

#[test]
fn shrinking_on_off_reach_same_dual_objective() {
    let (x, y) = ring_data(100, 4);
    let m = Matrix::Dense(x);
    let pre = kernel_matrix_sym(KernelKind::MinMax, &m);
    for c in [1.0, 32.0] {
        let on = train_binary(
            &pre,
            &y,
            &KernelSvmParams { c, shrink: true, max_epochs: 400, ..Default::default() },
        );
        let off = train_binary(
            &pre,
            &y,
            &KernelSvmParams { c, shrink: false, max_epochs: 400, ..Default::default() },
        );
        let o_on = dual_objective(&pre, &y, &on);
        let o_off = dual_objective(&pre, &y, &off);
        assert!(
            (o_on - o_off).abs() < 1e-2 * (1.0 + o_off.abs()),
            "C={c}: shrink {o_on} vs plain {o_off}"
        );
    }
}

#[test]
fn hot_cache_serves_retraining_without_recomputation() {
    let n = 80;
    let (x, y) = ring_data(n, 5);
    let m = Matrix::Dense(x);
    let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(n);
    let p = KernelSvmParams { c: 4.0, ..Default::default() };
    let first = train_binary_on(&otf, &y, &p);
    let computed_after_first = otf.stats().rows_computed;
    assert!(computed_after_first <= n, "a full-size cache must never recompute a row");
    let second = train_binary_on(&otf, &y, &p);
    assert_models_bit_identical(&first, &second);
    assert_eq!(
        otf.stats().rows_computed,
        computed_after_first,
        "hot retrain must be served entirely from cache"
    );
    // rows_materialized is the bench's peak-memory proxy.
    assert_eq!(otf.rows_materialized(), computed_after_first);
}

#[test]
fn bounded_cache_records_materialization_work() {
    let n = 80;
    let (x, y) = ring_data(n, 6);
    let m = Matrix::Dense(x);
    let cap = n / 4;
    let otf = OnTheFly::new(KernelKind::MinMax, &m).with_cache_rows(cap);
    let p = KernelSvmParams { c: 4.0, ..Default::default() };
    let _ = train_binary_on(&otf, &y, &p);
    let stats = otf.stats();
    assert!(stats.rows_computed > 0, "training must touch kernel rows");
    assert!(otf.cached_rows() <= cap, "resident rows exceed the cap");
}
