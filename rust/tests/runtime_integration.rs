//! Integration tests across the AOT boundary: the PJRT executables
//! (python-lowered Pallas kernels) must agree with the rust-native
//! implementations on identical inputs and randomness.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts directory is absent so `cargo test` works standalone.

use minmax::cws::{materialize_params, CwsHasher};
use minmax::data::dense::Dense;
use minmax::data::Matrix;
use minmax::kernels::matrix::kernel_matrix;
use minmax::kernels::KernelKind;
use minmax::runtime::{default_artifacts_dir, literal_f32, Engine};
use minmax::util::rng::Pcg64;

fn engine_or_skip(names: &[&str]) -> Option<Engine> {
    if !minmax::runtime::pjrt_enabled() {
        eprintln!("SKIP: built without the `pjrt` feature");
        return None;
    }
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::load_subset_hack(&dir, names))
}

// Small helper trait hack so tests read naturally without re-exporting
// internals: Engine::load_subset returns Result; unwrap here.
trait LoadHack {
    fn load_subset_hack(dir: &std::path::Path, names: &[&str]) -> Engine;
}
impl LoadHack for Engine {
    fn load_subset_hack(dir: &std::path::Path, names: &[&str]) -> Engine {
        Engine::load_subset(dir, names).expect("engine load")
    }
}

fn random_batch(rng: &mut Pcg64, b: usize, d: usize, zero_frac: f64) -> Vec<f32> {
    let mut x: Vec<f32> = (0..b * d)
        .map(|_| {
            if rng.uniform() < zero_frac {
                0.0
            } else {
                rng.lognormal(0.0, 1.0) as f32
            }
        })
        .collect();
    // no all-zero rows
    for row in 0..b {
        if x[row * d..(row + 1) * d].iter().all(|&v| v == 0.0) {
            x[row * d] = 1.0;
        }
    }
    x
}

#[test]
fn pjrt_cws_matches_rust_native() {
    let Some(engine) = engine_or_skip(&["cws_hash_small"]) else { return };
    let spec = engine.spec("cws_hash_small").unwrap().clone();
    let (b, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.inputs[1].shape[0];

    let seed = 20150704u64;
    let mut rng = Pcg64::new(9);
    let x = random_batch(&mut rng, b, d, 0.4);
    let (r, c, beta) = materialize_params(seed, d, k);

    let outs = engine
        .run_decoded(
            "cws_hash_small",
            &[
                literal_f32(&x, &[b, d]).unwrap(),
                literal_f32(&r, &[k, d]).unwrap(),
                literal_f32(&c, &[k, d]).unwrap(),
                literal_f32(&beta, &[k, d]).unwrap(),
            ],
        )
        .unwrap();
    let i_star = outs[0].as_i32().unwrap();
    let t_star = outs[1].as_i32().unwrap();
    assert_eq!(i_star.len(), b * k);

    // Rust-native hashing with the same counter-based randomness. The
    // native path computes in f64, the AOT path in f32 — argmin flips
    // from rounding are possible but must be rare (<1%).
    let hasher = CwsHasher::new(seed, k);
    let mut mismatches = 0usize;
    let mut t_mismatches = 0usize;
    for row in 0..b {
        let samples = hasher.hash_dense(&x[row * d..(row + 1) * d]);
        for (j, s) in samples.iter().enumerate() {
            if i_star[row * k + j] != s.i_star as i32 {
                mismatches += 1;
            } else if t_star[row * k + j] as i64 != s.t_star {
                t_mismatches += 1;
            }
        }
    }
    let total = b * k;
    assert!(
        (mismatches as f64) < 0.01 * total as f64,
        "i* mismatch rate {mismatches}/{total}"
    );
    assert!(
        (t_mismatches as f64) < 0.01 * total as f64,
        "t* mismatch rate {t_mismatches}/{total}"
    );
}

#[test]
fn pjrt_minmax_block_matches_rust_kernels() {
    let Some(engine) = engine_or_skip(&["minmax_block"]) else { return };
    let spec = engine.spec("minmax_block").unwrap().clone();
    let (m, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[0];

    let mut rng = Pcg64::new(11);
    let x = random_batch(&mut rng, m, d, 0.3);
    let y = random_batch(&mut rng, n, d, 0.3);

    let outs = engine
        .run_decoded(
            "minmax_block",
            &[literal_f32(&x, &[m, d]).unwrap(), literal_f32(&y, &[n, d]).unwrap()],
        )
        .unwrap();
    let k_pjrt = outs[0].as_f32().unwrap();

    let xm = Matrix::Dense(Dense::from_vec(m, d, x));
    let ym = Matrix::Dense(Dense::from_vec(n, d, y));
    let k_native = kernel_matrix(KernelKind::MinMax, &xm, &ym);
    for i in 0..m {
        for j in 0..n {
            let a = k_pjrt[i * n + j];
            let b_ = k_native.get(i, j);
            assert!((a - b_).abs() < 1e-5, "({i},{j}): pjrt {a} vs native {b_}");
        }
    }
}

#[test]
fn pjrt_linear_block_matches_dot() {
    let Some(engine) = engine_or_skip(&["linear_block"]) else { return };
    let spec = engine.spec("linear_block").unwrap().clone();
    let (m, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[0];
    let mut rng = Pcg64::new(13);
    let x = random_batch(&mut rng, m, d, 0.0);
    let y = random_batch(&mut rng, n, d, 0.0);
    let outs = engine
        .run_decoded(
            "linear_block",
            &[literal_f32(&x, &[m, d]).unwrap(), literal_f32(&y, &[n, d]).unwrap()],
        )
        .unwrap();
    let k = outs[0].as_f32().unwrap();
    for i in 0..m {
        for j in 0..n {
            let want: f64 = (0..d).map(|t| x[i * d + t] as f64 * y[j * d + t] as f64).sum();
            let got = k[i * n + j] as f64;
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want.abs()),
                "({i},{j}): {got} vs {want}"
            );
        }
    }
}

#[test]
fn pjrt_hash_score_matches_native_scoring() {
    let Some(engine) = engine_or_skip(&["hash_score"]) else { return };
    let spec = engine.spec("hash_score").unwrap().clone();
    let (b, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.inputs[1].shape[0];
    let codes = spec.inputs[4].shape[1];
    let classes = spec.inputs[4].shape[2];

    let seed = 77u64;
    let mut rng = Pcg64::new(15);
    let x = random_batch(&mut rng, b, d, 0.2);
    let (r, c, beta) = materialize_params(seed, d, k);
    let w: Vec<f32> = (0..k * codes * classes).map(|_| rng.normal() as f32).collect();

    let outs = engine
        .run_decoded(
            "hash_score",
            &[
                literal_f32(&x, &[b, d]).unwrap(),
                literal_f32(&r, &[k, d]).unwrap(),
                literal_f32(&c, &[k, d]).unwrap(),
                literal_f32(&beta, &[k, d]).unwrap(),
                literal_f32(&w, &[k, codes, classes]).unwrap(),
            ],
        )
        .unwrap();
    let scores = outs[0].as_f32().unwrap();
    assert_eq!(scores.len(), b * classes);

    // Native: hash, code, gather-sum. Tolerate rare argmin flips by
    // checking that the vast majority of rows agree closely.
    let hasher = CwsHasher::new(seed, k);
    let mut rows_ok = 0usize;
    for row in 0..b {
        let samples = hasher.hash_dense(&x[row * d..(row + 1) * d]);
        let mut want = vec![0.0f64; classes];
        for (j, s) in samples.iter().enumerate() {
            let code = (s.i_star as usize) % codes;
            for cl in 0..classes {
                want[cl] += w[(j * codes + code) * classes + cl] as f64;
            }
        }
        let ok = (0..classes).all(|cl| {
            (scores[row * classes + cl] as f64 - want[cl]).abs() < 1e-3 * (1.0 + want[cl].abs())
        });
        if ok {
            rows_ok += 1;
        }
    }
    assert!(rows_ok * 100 >= b * 95, "only {rows_ok}/{b} rows agree");
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(engine) = engine_or_skip(&["minmax_block"]) else { return };
    // Wrong arity.
    let x = literal_f32(&[1.0; 4], &[2, 2]).unwrap();
    assert!(engine.run("minmax_block", &[x]).is_err());
    // Wrong element count.
    let bad1 = literal_f32(&[1.0; 4], &[2, 2]).unwrap();
    let bad2 = literal_f32(&[1.0; 4], &[2, 2]).unwrap();
    assert!(engine.run("minmax_block", &[bad1, bad2]).is_err());
    // Unknown name.
    assert!(engine.run("nope", &[]).is_err());
}
