//! Concurrency / unsafe-hygiene lints (DESIGN.md §2.8, §2.9).
//!
//! Five rules, all checked over *code only* — a comment/string stripper
//! runs first so prose can mention the banned tokens freely:
//!
//! 1. **safety-comment** — every `unsafe` token needs a `// SAFETY:`
//!    comment (or, for `unsafe fn` declarations, a `/// # Safety` doc
//!    section) within the 8 lines above it (applies everywhere,
//!    including test modules: unjustified unsafe is never fine).
//! 2. **relaxed-ordering** — `Ordering::Relaxed` is banned in
//!    `rust/src` unless a `relaxed-ok:` marker within the 6 lines
//!    above states why the site is a pure hint/tally (routing hints
//!    and monotonic observability counters qualify; lifecycle flags
//!    and anything another thread's reads depend on do not — see the
//!    `service.rs` `stopping`-flag regression, ISSUE 9).
//! 3. **std-sync-ban** — `std::sync` / `std::thread` are banned in
//!    `rust/src/coordinator/` and `rust/src/util/pool.rs`: those
//!    modules must go through the `util::sync` facade so the loom
//!    build (`--cfg loom`) model-checks the real code paths. The
//!    facade itself (`util/sync.rs`) is the one sanctioned importer.
//! 4. **hash-collection** — `HashMap`/`HashSet` are banned in the
//!    output-producing subsystems (`cws`, `features`, `serve`,
//!    `coordinator`, `kernels`) unless a `hash-ok:` marker explains
//!    why iteration order cannot reach any output (RandomState makes
//!    iteration order run-dependent, which breaks bit-reproducibility
//!    — the same reason `cws::lsh` moved to open addressing).
//! 5. **bare-spawn** — `thread::spawn` is banned in
//!    `rust/src/coordinator/`: serving threads must go through
//!    `util::sync::spawn_named` so every worker/supervisor thread is
//!    named (panic reports and debugger output identify the shard and
//!    incarnation — DESIGN.md §2.9's supervision protocol depends on
//!    it) and spawn failures surface as `Result` instead of a panic in
//!    the startup path.
//!
//! Rules 2–5 skip everything from the first `#[cfg(test)]` line to end
//! of file (test modules sit at the bottom of every file in this repo
//! and may use std primitives or hash maps freely).

use std::io;
use std::path::{Path, PathBuf};

/// One lint hit; `run` prints these `file:line: [lint] message`.
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub lint: &'static str,
    pub msg: String,
}

/// Directories scanned by `run`, relative to the repo root.
const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "xtask/src"];

/// Walk the scan dirs and lint every `.rs` file; returns the violation
/// count (0 = clean).
pub fn run(root: &Path) -> io::Result<usize> {
    let mut files = Vec::new();
    for dir in SCAN_DIRS {
        collect_rs(&root.join(dir), &mut files)?;
    }
    files.sort();
    let mut n = 0;
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let content = std::fs::read_to_string(f)?;
        for v in check_file(&rel, &content) {
            eprintln!("{}:{}: [{}] {}", v.file, v.line, v.lint, v.msg);
            n += 1;
        }
    }
    Ok(n)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file. Pure function of `(relpath, content)` so the negative
/// fixtures below can seed violations without touching the filesystem.
pub fn check_file(relpath: &str, content: &str) -> Vec<Violation> {
    let stripped = strip_comments_and_strings(content);
    let raw: Vec<&str> = content.lines().collect();
    let code: Vec<&str> = stripped.lines().collect();
    // Everything at/after the first `#[cfg(test)]` is test scaffolding.
    let cut = raw.iter().position(|l| l.contains("#[cfg(test)]")).unwrap_or(raw.len());

    let in_src = relpath.starts_with("rust/src/");
    let std_banned = (relpath.starts_with("rust/src/coordinator/")
        || relpath == "rust/src/util/pool.rs")
        && relpath != "rust/src/util/sync.rs";
    let hash_scoped = ["cws", "features", "serve", "coordinator", "kernels"]
        .iter()
        .any(|m| relpath.starts_with(&format!("rust/src/{m}/")));

    let mut out = Vec::new();
    for (idx, line) in code.iter().enumerate() {
        if has_word(line, "unsafe")
            && !marker_above(&raw, idx, "SAFETY:", 8)
            && !marker_above(&raw, idx, "# Safety", 8)
        {
            out.push(Violation {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "safety-comment",
                msg: "`unsafe` without a `// SAFETY:` comment in the 8 lines above".to_string(),
            });
        }
        if idx >= cut {
            continue;
        }
        if in_src && has_word(line, "Relaxed") && !marker_above(&raw, idx, "relaxed-ok", 6) {
            out.push(Violation {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "relaxed-ordering",
                msg: "`Ordering::Relaxed` without a `relaxed-ok:` marker".to_string(),
            });
        }
        if std_banned && (has_word(line, "std::sync") || has_word(line, "std::thread")) {
            out.push(Violation {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "std-sync-ban",
                msg: "use the `util::sync` facade so loom can model this module".to_string(),
            });
        }
        if relpath.starts_with("rust/src/coordinator/") && has_word(line, "thread::spawn") {
            out.push(Violation {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "bare-spawn",
                msg: "spawn serving threads via `util::sync::spawn_named` (named for \
                      supervision, fallible startup)"
                    .to_string(),
            });
        }
        if hash_scoped
            && (has_word(line, "HashMap") || has_word(line, "HashSet"))
            && !marker_above(&raw, idx, "hash-ok", 6)
        {
            out.push(Violation {
                file: relpath.to_string(),
                line: idx + 1,
                lint: "hash-collection",
                msg: "HashMap/HashSet without a `hash-ok:` marker in an output path".to_string(),
            });
        }
    }
    out
}

/// True if any raw line in `[idx - window, idx]` contains `marker`
/// (markers live in comments, so this looks at the *unstripped* text).
fn marker_above(raw: &[&str], idx: usize, marker: &str, window: usize) -> bool {
    let lo = idx.saturating_sub(window);
    raw[lo..=idx.min(raw.len().saturating_sub(1))].iter().any(|l| l.contains(marker))
}

fn is_word_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Substring match with identifier boundaries on both ends, so
/// `unsafe_op_in_unsafe_fn` does not count as `unsafe` and
/// `std::synchronize` would not count as `std::sync`.
fn has_word(line: &str, word: &str) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let p = start + pos;
        let end = p + word.len();
        let before_ok = p == 0 || !is_word_byte(bytes[p - 1]);
        let after_ok = end >= bytes.len() || !is_word_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = end;
    }
    false
}

/// Replace comment and string-literal *contents* with spaces, byte for
/// byte, preserving newlines — the output has the same line structure
/// as the input, with only real code left. Handles `//` and nested
/// `/* */` comments, `"…"` strings with escapes, `r"…"`/`r#"…"#` raw
/// strings, and char literals vs. lifetimes (`'x'` vs `'a`).
fn strip_comments_and_strings(content: &str) -> String {
    let b = content.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut i = 0;
    let blank = |c: u8| if c == b'\n' { b'\n' } else { b' ' };
    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            while i < b.len() && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nests in Rust).
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            out.extend_from_slice(b"  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: r"…" or r#"…"# (any hash count), only when the
        // `r` is not the tail of an identifier.
        if c == b'r' && (i == 0 || !is_word_byte(b[i - 1])) {
            let mut j = i + 1;
            while b.get(j) == Some(&b'#') {
                j += 1;
            }
            if b.get(j) == Some(&b'"') {
                let hashes = j - (i + 1);
                for _ in i..=j {
                    out.push(b' ');
                }
                i = j + 1;
                while i < b.len() {
                    if b[i] == b'"'
                        && i + 1 + hashes <= b.len()
                        && b[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#')
                    {
                        for _ in 0..=hashes {
                            out.push(b' ');
                        }
                        i += 1 + hashes;
                        break;
                    }
                    out.push(blank(b[i]));
                    i += 1;
                }
                continue;
            }
        }
        // Ordinary string literal.
        if c == b'"' {
            out.push(b' ');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if b[i] == b'"' {
                    out.push(b' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs. lifetime: only literals close with a quote
        // right after one (possibly escaped) character.
        if c == b'\'' {
            if b.get(i + 1) == Some(&b'\\') {
                out.extend_from_slice(b"  ");
                i += 2;
                while i < b.len() && b[i] != b'\'' {
                    out.push(blank(b[i]));
                    i += 1;
                }
                if i < b.len() {
                    out.push(b' ');
                    i += 1;
                }
            } else if b.get(i + 2) == Some(&b'\'') {
                out.extend_from_slice(b"   ");
                i += 3;
            } else {
                out.push(c);
                i += 1;
            }
            continue;
        }
        out.push(c);
        i += 1;
    }
    String::from_utf8(out).expect("stripper replaces whole bytes only")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lints(path: &str, src: &str) -> Vec<&'static str> {
        check_file(path, src).iter().map(|v| v.lint).collect()
    }

    // The negative fixture the ISSUE demands: a seeded violation must
    // fail the lint, and the marker/comment must clear it.
    #[test]
    fn seeded_unsafe_without_safety_comment_fails() {
        let bad = "fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";
        assert_eq!(lints("rust/src/util/x.rs", bad), ["safety-comment"]);
        let good = "fn f(p: *mut u8) {\n    // SAFETY: p is valid.\n    unsafe { *p = 0 };\n}\n";
        assert!(lints("rust/src/util/x.rs", good).is_empty());
    }

    #[test]
    fn safety_doc_section_clears_unsafe_fn() {
        let src = "/// # Safety\n/// `p` must be valid.\npub unsafe fn f(p: *mut u8) {}\n";
        assert!(lints("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn safety_lint_applies_inside_test_modules_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(p: *mut u8) { unsafe { *p = 0 }; }\n}\n";
        assert_eq!(lints("rust/src/util/x.rs", src), ["safety-comment"]);
    }

    #[test]
    fn seeded_relaxed_without_marker_fails() {
        let bad = "fn f(a: &AtomicU64) {\n    a.load(Ordering::Relaxed);\n}\n";
        assert_eq!(lints("rust/src/serve/x.rs", bad), ["relaxed-ordering"]);
        let good =
            "fn f(a: &AtomicU64) {\n    // relaxed-ok: tally.\n    a.load(Ordering::Relaxed);\n}\n";
        assert!(lints("rust/src/serve/x.rs", good).is_empty());
        // Out of scope: benches measure, they do not synchronize.
        assert!(lints("rust/benches/x.rs", bad).is_empty());
    }

    #[test]
    fn seeded_std_sync_in_coordinator_fails() {
        let bad = "use std::sync::Mutex;\nuse std::thread;\n";
        assert_eq!(lints("rust/src/coordinator/x.rs", bad), ["std-sync-ban", "std-sync-ban"]);
        assert_eq!(lints("rust/src/util/pool.rs", bad), ["std-sync-ban", "std-sync-ban"]);
        // The facade is the sanctioned importer; other modules are free.
        assert!(lints("rust/src/util/sync.rs", bad).is_empty());
        assert!(lints("rust/src/serve/x.rs", bad).is_empty());
    }

    #[test]
    fn seeded_bare_spawn_in_coordinator_fails() {
        let bad = "fn f() {\n    thread::spawn(|| {});\n}\n";
        assert_eq!(lints("rust/src/coordinator/x.rs", bad), ["bare-spawn"]);
        // Fully-qualified spawn trips both the facade ban and the
        // spawn ban.
        let qualified = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
        assert_eq!(lints("rust/src/coordinator/x.rs", qualified), ["std-sync-ban", "bare-spawn"]);
        let good = "fn f() {\n    spawn_named(\"minmax-w0\".into(), || {}).unwrap();\n}\n";
        assert!(lints("rust/src/coordinator/x.rs", good).is_empty());
        // Out of scope: other modules and test code may spawn freely.
        assert!(lints("rust/src/util/x.rs", bad).is_empty());
        let in_tests =
            "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g() { thread::spawn(|| {}); }\n}\n";
        assert!(lints("rust/src/coordinator/x.rs", in_tests).is_empty());
    }

    #[test]
    fn test_modules_exempt_from_scoped_lints() {
        let src = "pub fn f() {}\n#[cfg(test)]\nmod tests {\n    use std::sync::Arc;\n    \
                   use std::collections::HashMap;\n    \
                   fn g(a: &AtomicU64) { a.load(Ordering::Relaxed); }\n}\n";
        assert!(lints("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn seeded_hash_map_without_marker_fails() {
        let bad = "use std::collections::HashMap;\n";
        assert_eq!(lints("rust/src/cws/x.rs", bad), ["hash-collection"]);
        let good = "// hash-ok: keyed lookups only.\nuse std::collections::HashMap;\n";
        assert!(lints("rust/src/cws/x.rs", good).is_empty());
        // util/ and data/ are out of scope for the hash lint.
        assert!(lints("rust/src/util/x.rs", bad).is_empty());
    }

    #[test]
    fn comments_strings_and_identifiers_do_not_trip() {
        let src = "//! prose: unsafe, Ordering::Relaxed, HashMap\n\
                   #![deny(unsafe_op_in_unsafe_fn)]\n\
                   /* block: std::sync unsafe */\n\
                   fn f() -> &'static str {\n    \"unsafe HashMap std::thread\"\n}\n\
                   fn g() -> String {\n    r\"unsafe Relaxed\".to_string()\n}\n";
        assert!(lints("rust/src/coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn stripper_preserves_line_structure() {
        let src = "a // x\nb \"two\nlines\" c\n'q' 'l\n";
        let stripped = strip_comments_and_strings(src);
        assert_eq!(stripped.lines().count(), src.lines().count());
        assert!(stripped.starts_with("a "));
        assert!(!stripped.contains("two"));
        // The lifetime tick survives as code; the char literal is gone.
        assert!(stripped.contains("'l"));
        assert!(!stripped.contains('q'));
    }

    #[test]
    fn marker_window_is_bounded() {
        // A marker 7 lines up is out of the 6-line relaxed window.
        let far = "// relaxed-ok: too far away.\n\n\n\n\n\n\n\
                   fn f(a: &AtomicU64) { a.load(Ordering::Relaxed); }\n";
        assert_eq!(lints("rust/src/serve/x.rs", far), ["relaxed-ordering"]);
    }
}
