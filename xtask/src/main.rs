//! `cargo xtask` — dependency-free repo automation.
//!
//! Subcommands:
//!
//! * `lint` — the concurrency/unsafe hygiene lints over
//!   `rust/{src,benches,tests}` and `xtask/src` (see [`lint`] for the
//!   rule catalogue and DESIGN.md §2.8 for the rationale). Exits
//!   non-zero on any violation; CI runs it in the `lint` job.

use std::path::PathBuf;
use std::process::ExitCode;

mod lint;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => match lint::run(&repo_root()) {
            Ok(0) => {
                println!("xtask lint: clean");
                ExitCode::SUCCESS
            }
            Ok(n) => {
                eprintln!("xtask lint: {n} violation(s)");
                ExitCode::FAILURE
            }
            Err(e) => {
                eprintln!("xtask lint: {e}");
                ExitCode::FAILURE
            }
        },
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::FAILURE
        }
    }
}

/// The workspace root: xtask always lives one level below it.
fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().expect("xtask has a parent").to_path_buf()
}
