"""Cross-language contract tests for the counter-based CWS parameters.

The same golden vectors are asserted by rust unit tests
(`cws::sampler::tests::golden_params_cross_language`), pinning both
implementations to one specification.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import params


def test_golden_vectors_exact():
    params.check_golden()


def test_materialize_matches_pointwise():
    r, c, b = params.materialize(7, d=5, k=3)
    assert r.shape == (3, 5)
    for j in range(3):
        for i in range(5):
            rr, cc, bb = params.params_at(7, j, i)
            assert r[j, i] == np.float32(rr)
            assert c[j, i] == np.float32(cc)
            assert b[j, i] == np.float32(bb)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**63 - 1),
    j=st.integers(0, 2**32 - 1),
    i=st.integers(0, 2**32 - 1),
)
def test_distribution_ranges(seed, j, i):
    r, c, b = params.params_at(seed, j, i)
    assert float(r) > 0.0
    assert float(c) > 0.0
    assert 0.0 <= float(b) < 1.0


def test_gamma2_moments():
    rng = np.random.default_rng(0)
    jj = rng.integers(0, 1 << 31, size=50_000)
    ii = rng.integers(0, 1 << 31, size=50_000)
    r, c, b = params.params_at(9, jj, ii)
    assert abs(r.mean() - 2.0) < 0.05
    assert abs(r.var() - 2.0) < 0.15
    assert abs(c.mean() - 2.0) < 0.05
    assert abs(b.mean() - 0.5) < 0.01


def test_params_feed_cws_ref_consistently(np_rng):
    # Hash with ref.cws_ref using materialize()-derived matrices; the
    # result must be deterministic in the seed.
    from compile.kernels import ref
    from .conftest import make_data

    x = make_data(np_rng, 4, 16)
    r, c, b = params.materialize(123, d=16, k=8)
    i1, t1 = ref.cws_ref(x, r, c, b)
    i2, t2 = ref.cws_ref(x, r, c, b)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    r2, _, _ = params.materialize(124, d=16, k=8)
    assert (np.asarray(r) != np.asarray(r2)).any()
