"""Pallas ICWS kernel vs the pure-jnp oracle — the core L1 correctness
signal, including a hypothesis sweep over shapes and block configs."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cws, ref
from .conftest import make_data, make_params


def run_both(x, r, c, beta, **kw):
    got_i, got_t = cws.cws_hash(x, r, c, beta, **kw)
    want_i, want_t = ref.cws_ref(x, r, c, beta)
    return (np.asarray(got_i), np.asarray(got_t)), (
        np.asarray(want_i),
        np.asarray(want_t),
    )


def test_matches_ref_default_blocks(np_rng):
    x = make_data(np_rng, 16, 64)
    r, c, beta = make_params(np_rng, 32, 64)
    (gi, gt), (wi, wt) = run_both(x, r, c, beta)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(gt, wt)


def test_matches_ref_asymmetric_blocks(np_rng):
    x = make_data(np_rng, 12, 40)
    r, c, beta = make_params(np_rng, 24, 40)
    (gi, gt), (wi, wt) = run_both(x, r, c, beta, block_b=4, block_k=8, block_d=16)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(gt, wt)


def test_blocking_does_not_change_result(np_rng):
    x = make_data(np_rng, 8, 96)
    r, c, beta = make_params(np_rng, 16, 96)
    base = None
    for bb, bk, bd in [(8, 16, 128), (4, 8, 32), (2, 16, 96), (8, 4, 7)]:
        gi, gt = cws.cws_hash(x, r, c, beta, block_b=bb, block_k=bk, block_d=bd)
        gi, gt = np.asarray(gi), np.asarray(gt)
        if base is None:
            base = (gi, gt)
        else:
            np.testing.assert_array_equal(gi, base[0], err_msg=f"{bb},{bk},{bd}")
            np.testing.assert_array_equal(gt, base[1], err_msg=f"{bb},{bk},{bd}")


def test_zero_entries_never_selected(np_rng):
    x = make_data(np_rng, 8, 32, zero_frac=0.8)
    r, c, beta = make_params(np_rng, 16, 32)
    gi, _ = cws.cws_hash(x, r, c, beta)
    gi = np.asarray(gi)
    for b in range(8):
        for k in range(16):
            assert x[b, gi[b, k]] > 0.0


def test_identical_rows_hash_identically(np_rng):
    x0 = make_data(np_rng, 1, 48)
    x = np.vstack([x0, x0, x0, x0])
    r, c, beta = make_params(np_rng, 16, 48)
    gi, gt = cws.cws_hash(x, r, c, beta)
    gi, gt = np.asarray(gi), np.asarray(gt)
    for b in range(1, 4):
        np.testing.assert_array_equal(gi[b], gi[0])
        np.testing.assert_array_equal(gt[b], gt[0])


def test_collision_probability_tracks_minmax(np_rng):
    # Eq. (7)/(8) sanity through the kernel itself: the (i*, t*)
    # collision fraction over k samples approximates K_MM.
    d = 64
    u = make_data(np_rng, 1, d, zero_frac=0.2)[0]
    v = u * np_rng.lognormal(0.0, 0.5, size=d).astype(np.float32)
    x = np.stack([u, v])
    k = 512
    r, c, beta = make_params(np_rng, k, d)
    gi, gt = cws.cws_hash(x, r, c, beta, block_b=2, block_k=16)
    gi, gt = np.asarray(gi), np.asarray(gt)
    kmm = float(np.minimum(u, v).sum() / np.maximum(u, v).sum())
    full = float(np.mean((gi[0] == gi[1]) & (gt[0] == gt[1])))
    zero = float(np.mean(gi[0] == gi[1]))
    tol = 4.0 * np.sqrt(kmm * (1 - kmm) / k) + 0.02
    assert abs(full - kmm) < tol, (full, kmm)
    assert abs(zero - kmm) < tol, (zero, kmm)


@settings(max_examples=12, deadline=None)
@given(
    b_pow=st.integers(0, 3),
    k_pow=st.integers(0, 3),
    d=st.integers(3, 80),
    zero_frac=st.floats(0.0, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep_matches_ref(b_pow, k_pow, d, zero_frac, seed):
    rng = np.random.default_rng(seed)
    b, k = 2**b_pow, 2**k_pow
    x = make_data(rng, b, d, zero_frac)
    r, c, beta = make_params(rng, k, d)
    (gi, gt), (wi, wt) = run_both(
        x, r, c, beta, block_b=min(4, b), block_k=min(4, k), block_d=32
    )
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(gt, wt)


def test_indivisible_batch_rejected(np_rng):
    x = make_data(np_rng, 6, 16)
    r, c, beta = make_params(np_rng, 8, 16)
    with pytest.raises(AssertionError):
        cws.cws_hash(x, r, c, beta, block_b=4, block_k=8)


def test_vmem_estimate_reasonable():
    # Default config must fit a 16 MiB VMEM budget with margin.
    bytes_ = cws.vmem_estimate_bytes(
        cws.DEFAULT_BLOCK_B, cws.DEFAULT_BLOCK_K, 128, 256
    )
    assert bytes_ < 4 * 1024 * 1024, bytes_
