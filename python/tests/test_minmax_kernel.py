"""Pallas min-max Gram kernel vs the pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import minmax, ref
from .conftest import make_data


def test_matches_ref(np_rng):
    x = make_data(np_rng, 32, 64)
    y = make_data(np_rng, 32, 64)
    got = np.asarray(minmax.minmax_matrix(x, y))
    want = np.asarray(ref.minmax_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_blocking_invariance(np_rng):
    x = make_data(np_rng, 16, 48)
    y = make_data(np_rng, 24, 48)
    base = np.asarray(minmax.minmax_matrix(x, y, block_m=16, block_n=24))
    for bm, bn, bd in [(4, 8, 16), (8, 12, 48), (16, 24, 7), (2, 2, 1)]:
        got = np.asarray(minmax.minmax_matrix(x, y, block_m=bm, block_n=bn, block_d=bd))
        np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-7)


def test_self_gram_diag_is_one(np_rng):
    x = make_data(np_rng, 16, 32)
    k = np.asarray(minmax.minmax_matrix(x, x))
    np.testing.assert_allclose(np.diag(k), 1.0, rtol=1e-6)
    # symmetric
    np.testing.assert_allclose(k, k.T, rtol=1e-6, atol=1e-7)


def test_bounded_01(np_rng):
    x = make_data(np_rng, 8, 40, zero_frac=0.6)
    y = make_data(np_rng, 8, 40, zero_frac=0.6)
    k = np.asarray(minmax.minmax_matrix(x, y))
    assert (k >= 0).all() and (k <= 1 + 1e-6).all()


def test_zero_rows_convention():
    x = np.zeros((4, 8), dtype=np.float32)
    y = np.zeros((4, 8), dtype=np.float32)
    x[0, 0] = 1.0  # one nonzero row
    k = np.asarray(minmax.minmax_matrix(x, y))
    # zero-vs-zero = 1.0 (identical), nonzero-vs-zero = 0.0
    assert k[1, 0] == 1.0
    assert k[0, 0] == 0.0


def test_linear_block_matches_dot(np_rng):
    x = make_data(np_rng, 16, 32)
    y = make_data(np_rng, 8, 32)
    got = np.asarray(minmax.linear_matrix(x, y, block_m=8, block_n=8))
    np.testing.assert_allclose(got, x @ y.T, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m_pow=st.integers(0, 3),
    n_pow=st.integers(0, 3),
    d=st.integers(1, 64),
    zero_frac=st.floats(0.0, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(m_pow, n_pow, d, zero_frac, seed):
    rng = np.random.default_rng(seed)
    m, n = 2**m_pow, 2**n_pow
    x = rng.lognormal(0.0, 1.0, size=(m, d)).astype(np.float32)
    y = rng.lognormal(0.0, 1.0, size=(n, d)).astype(np.float32)
    x[rng.uniform(size=(m, d)) < zero_frac] = 0.0
    y[rng.uniform(size=(n, d)) < zero_frac] = 0.0
    got = np.asarray(
        minmax.minmax_matrix(x, y, block_m=min(4, m), block_n=min(4, n), block_d=16)
    )
    want = np.asarray(ref.minmax_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)


def test_vmem_estimate_reasonable():
    bytes_ = minmax.vmem_estimate_bytes(
        minmax.DEFAULT_BLOCK_M, minmax.DEFAULT_BLOCK_N, 128, 256
    )
    assert bytes_ < 4 * 1024 * 1024, bytes_
