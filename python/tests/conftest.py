import os
import sys

import numpy as np
import pytest

# Tests may be launched from the repo root or from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def make_params(rng: np.random.Generator, k: int, d: int):
    """(r, c, beta) matrices with the Algorithm-1 distributions."""
    r = rng.gamma(2.0, 1.0, size=(k, d)).astype(np.float32)
    c = rng.gamma(2.0, 1.0, size=(k, d)).astype(np.float32)
    beta = rng.uniform(0.0, 1.0, size=(k, d)).astype(np.float32)
    return r, c, beta


def make_data(rng: np.random.Generator, b: int, d: int, zero_frac: float = 0.3):
    """Nonnegative heavy-tailed data batch with exact zeros."""
    x = rng.lognormal(0.0, 1.0, size=(b, d)).astype(np.float32)
    mask = rng.uniform(size=(b, d)) < zero_frac
    x[mask] = 0.0
    # Ensure no all-zero rows (CWS is undefined there).
    for i in range(b):
        if not x[i].any():
            x[i, rng.integers(0, d)] = 1.0
    return x


@pytest.fixture
def np_rng():
    return np.random.default_rng(2015)
