"""AOT lowering: HLO-text generation and manifest integrity."""

import json
import os

import pytest

from compile import aot


def test_lower_small_variant_produces_hlo_text():
    lowered, ins, outs = aot.lower_variant("cws_hash_small", aot.VARIANTS["cws_hash_small"])
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 1000
    assert ins[0][0] == "x" and outs[0][0] == "i_star"


def test_all_variants_lower():
    for name, spec in aot.VARIANTS.items():
        lowered, ins, outs = aot.lower_variant(name, spec)
        assert lowered is not None, name
        assert ins and outs, name


def test_unknown_variant_rejected():
    with pytest.raises(ValueError):
        aot.lower_variant("nope", {})


def test_manifest_on_disk_if_built():
    # `make artifacts` output, when present, must be consistent.
    root = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "artifacts")
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    for name, entry in manifest["entries"].items():
        path = os.path.join(root, entry["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, name
        assert entry["inputs"] and entry["outputs"], name
