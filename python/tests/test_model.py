"""Layer-2 model graphs: composition correctness and scorer semantics."""

import numpy as np

from compile import model
from compile.kernels import ref
from .conftest import make_data, make_params


def test_hash_and_score_equals_composition(np_rng):
    b, d, k, bits, cls = 8, 32, 16, 4, 3
    x = make_data(np_rng, b, d)
    r, c, beta = make_params(np_rng, k, d)
    w = np_rng.normal(size=(k, 1 << bits, cls)).astype(np.float32)
    fused = np.asarray(model.hash_and_score(x, r, c, beta, w))
    i_star, _ = ref.cws_ref(x, r, c, beta)
    codes = np.asarray(i_star) % (1 << bits)
    want = np.asarray(ref.score_ref(codes, w))
    np.testing.assert_allclose(fused, want, rtol=1e-6, atol=1e-6)


def test_score_ref_equals_onehot_matmul(np_rng):
    # The gather-scorer must equal the explicit one-hot × W product —
    # i.e. exactly the linear model the rust LIBLINEAR-style solver
    # trains on expanded features.
    b, k, bits, cls = 6, 8, 3, 4
    codes = np_rng.integers(0, 1 << bits, size=(b, k)).astype(np.int32)
    w = np_rng.normal(size=(k, 1 << bits, cls)).astype(np.float32)
    got = np.asarray(ref.score_ref(codes, w))
    # Explicit expansion.
    onehot = np.zeros((b, k * (1 << bits)), dtype=np.float32)
    for i in range(b):
        for j in range(k):
            onehot[i, j * (1 << bits) + codes[i, j]] = 1.0
    w_flat = w.reshape(k * (1 << bits), cls)
    want = onehot @ w_flat
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_hash_batch_shapes(np_rng):
    x = make_data(np_rng, 16, 64)
    r, c, beta = make_params(np_rng, 32, 64)
    i_star, t_star = model.hash_batch(x, r, c, beta)
    assert i_star.shape == (16, 32) and t_star.shape == (16, 32)
    assert str(i_star.dtype) == "int32" and str(t_star.dtype) == "int32"


def test_minmax_block_matches_ref(np_rng):
    x = make_data(np_rng, 8, 32)
    y = make_data(np_rng, 8, 32)
    got = np.asarray(model.minmax_block(x, y))
    np.testing.assert_allclose(got, np.asarray(ref.minmax_ref(x, y)), rtol=1e-6)


def test_linear_block_matches_ref(np_rng):
    x = make_data(np_rng, 8, 32)
    y = make_data(np_rng, 8, 32)
    got = np.asarray(model.linear_block(x, y))
    np.testing.assert_allclose(got, x @ y.T, rtol=1e-5)
