"""AOT compile path: lower the Layer-2 graphs to HLO **text** artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --outdir ../artifacts

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the published xla crate's
XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with ``return_tuple=True``; the rust
side unwraps with ``to_tuple1``/``to_tuple``.

Each artifact is described in ``manifest.json`` (name, file, input/output
shapes + dtypes) consumed by ``rust/src/runtime/artifact.rs``.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default artifact variants. Shapes are chosen so one PJRT execute is a
# meaningful unit of coordinator work while staying quick to compile in
# interpret mode. The rust batcher pads batches to B.
VARIANTS = {
    "cws_hash": dict(b=64, d=256, k=128),
    "cws_hash_small": dict(b=16, d=64, k=64),
    "minmax_block": dict(m=64, n=64, d=256),
    "linear_block": dict(m=64, n=64, d=256),
    "hash_score": dict(b=64, d=256, k=128, bits=8, classes=16),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_variant(name: str, spec: dict):
    """Returns (lowered, input_descs, output_descs)."""
    if name.startswith("cws_hash"):
        b, d, k = spec["b"], spec["d"], spec["k"]
        lowered = jax.jit(model.hash_batch).lower(
            f32(b, d), f32(k, d), f32(k, d), f32(k, d)
        )
        ins = [("x", (b, d), "f32"), ("r", (k, d), "f32"), ("c", (k, d), "f32"),
               ("beta", (k, d), "f32")]
        outs = [("i_star", (b, k), "s32"), ("t_star", (b, k), "s32")]
    elif name.startswith("minmax_block"):
        m, n, d = spec["m"], spec["n"], spec["d"]
        lowered = jax.jit(model.minmax_block).lower(f32(m, d), f32(n, d))
        ins = [("x", (m, d), "f32"), ("y", (n, d), "f32")]
        outs = [("k", (m, n), "f32")]
    elif name.startswith("linear_block"):
        m, n, d = spec["m"], spec["n"], spec["d"]
        lowered = jax.jit(model.linear_block).lower(f32(m, d), f32(n, d))
        ins = [("x", (m, d), "f32"), ("y", (n, d), "f32")]
        outs = [("k", (m, n), "f32")]
    elif name.startswith("hash_score"):
        b, d, k = spec["b"], spec["d"], spec["k"]
        codes = 1 << spec["bits"]
        cls = spec["classes"]
        lowered = jax.jit(model.hash_and_score).lower(
            f32(b, d), f32(k, d), f32(k, d), f32(k, d), f32(k, codes, cls)
        )
        ins = [("x", (b, d), "f32"), ("r", (k, d), "f32"), ("c", (k, d), "f32"),
               ("beta", (k, d), "f32"), ("w", (k, codes, cls), "f32")]
        outs = [("scores", (b, cls), "f32")]
    else:
        raise ValueError(f"unknown variant {name}")
    return lowered, ins, outs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--variants", default=",".join(VARIANTS), help="comma-separated subset"
    )
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    manifest = {"format": "hlo-text", "entries": {}}
    for name in args.variants.split(","):
        name = name.strip()
        if not name:
            continue
        spec = VARIANTS[name]
        lowered, ins, outs = lower_variant(name, spec)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname,
            "spec": spec,
            "inputs": [
                {"name": n, "shape": list(s), "dtype": dt} for (n, s, dt) in ins
            ],
            "outputs": [
                {"name": n, "shape": list(s), "dtype": dt} for (n, s, dt) in outs
            ],
        }
        print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest.json with {len(manifest['entries'])} entries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
