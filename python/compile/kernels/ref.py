"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

These are the ground truth every Pallas kernel is tested against
(``python/tests/``), and they double as readable specifications:

* :func:`cws_ref` — Ioffe's ICWS (Algorithm 1 of the paper) applied to a
  batch, given externally supplied random matrices ``(r, c, beta)``.
  The rust coordinator materializes those matrices with the *same*
  counter-based recipe (``rust/src/cws/sampler.rs::materialize_params``),
  so rust-native hashing and the AOT executables agree.
* :func:`minmax_ref` — the min-max kernel matrix (Eq. 1).
* :func:`score_ref` — the hashed-feature linear scorer: one-hot(0-bit
  CWS codes) · W, evaluated as a gather (never materializing the one-hot).
"""

import jax.numpy as jnp

# Sentinel "a" value for masked (zero-weight) coordinates. Finite (not
# +inf) so the XLA CPU argmin lowering never sees NaN/inf comparisons.
# A plain Python float (NOT a jnp array): pallas kernels may not capture
# module-level traced constants.
BIG = 3.4e38


def cws_elements(x, r, c, beta):
    """The per-coordinate ICWS quantities, batched.

    Args:
      x: ``[B, D]`` nonnegative float32 data.
      r, c, beta: ``[K, D]`` float32 CWS parameter matrices
        (r, c ~ Gamma(2,1); beta ~ U[0,1)).

    Returns:
      (t, a): each ``[B, K, D]`` float32; ``a`` is BIG where ``x == 0``.
    """
    x = x[:, None, :]  # [B, 1, D]
    r_ = r[None, :, :]  # [1, K, D]
    c_ = c[None, :, :]
    b_ = beta[None, :, :]
    pos = x > 0
    logx = jnp.log(jnp.where(pos, x, 1.0))
    t = jnp.floor(logx / r_ + b_)
    # a = c / (y * exp(r)), y = exp(r (t - beta))  =>  a = c e^{-r(t-b)-r}
    a = c_ * jnp.exp(-r_ * (t - b_) - r_)
    a = jnp.where(pos, a, BIG)
    return t, a


def cws_ref(x, r, c, beta):
    """Reference ICWS hash of a batch.

    Returns:
      (i_star, t_star): each ``[B, K]`` int32 — the argmin index and the
      quantized offset at the argmin.
    """
    t, a = cws_elements(x, r, c, beta)
    i_star = jnp.argmin(a, axis=-1).astype(jnp.int32)  # [B, K]
    t_star = jnp.take_along_axis(t, i_star[..., None], axis=-1)
    t_star = jnp.clip(t_star[..., 0], -2.0e9, 2.0e9).astype(jnp.int32)
    return i_star, t_star


def minmax_ref(x, y):
    """Min-max kernel matrix: ``K[i, j] = sum min(xi, yj) / sum max(xi, yj)``.

    Args:
      x: ``[M, D]``; y: ``[N, D]`` — nonnegative float32.

    Returns:
      ``[M, N]`` float32 in [0, 1]; pairs of all-zero rows give 1.0
      (identical inputs), matching the rust convention.
    """
    xs = x[:, None, :]
    ys = y[None, :, :]
    smin = jnp.sum(jnp.minimum(xs, ys), axis=-1)
    smax = jnp.sum(jnp.maximum(xs, ys), axis=-1)
    return jnp.where(smax > 0, smin / jnp.where(smax > 0, smax, 1.0), 1.0)


def linear_ref(x, y):
    """Linear kernel matrix ``x @ y.T`` (the MXU-friendly baseline tile)."""
    return x @ y.T


def score_ref(codes, w):
    """Hashed-feature linear scorer.

    Args:
      codes: ``[B, K]`` int32 in ``[0, 2^b)`` — the 0-bit CWS codes
        (``i* mod 2^b``) per sample slot.
      w: ``[K, 2^b, C]`` float32 — per-slot weight blocks of the linear
        model (the reshaped LIBLINEAR weight vector).

    Returns:
      ``[B, C]`` scores: ``sum_k w[k, codes[b, k], :]``.
    """
    gathered = jnp.take_along_axis(
        w[None, :, :, :],  # [1, K, 2^b, C]
        codes[:, :, None, None].astype(jnp.int32).clip(0, w.shape[1] - 1),
        axis=2,
    )  # [B, K, 1, C]
    return jnp.sum(gathered[:, :, 0, :], axis=1)
