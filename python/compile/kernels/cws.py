"""Layer-1 Pallas kernel: the ICWS sampling hot-spot.

The ICWS inner loop (per batch row, per hash slot: a masked argmin of
``a = c * exp(-r*(t-beta) - r)`` over the D data coordinates) is the
paper's computational bottleneck for large-scale hashing. This kernel
tiles it for VMEM:

* grid = (B / BB, K / BK) — one program instance produces a
  ``[BB, BK]`` tile of ``(i*, t*)``;
* the ``[BB, D]`` data panel and the three ``[BK, D]`` parameter panels
  stream HBM->VMEM once per grid step (BlockSpec);
* the ``[BB, BK, D]`` intermediate lives only in VMEM/registers, and the
  argmin is carried as a running (value, index) pair — the TPU analog of
  what a CUDA design would do with warp-shuffle reductions (DESIGN.md
  §Hardware-Adaptation).

MUST run with ``interpret=True`` on CPU: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Numerics are validated
against :mod:`.ref` by ``python/tests/test_cws_kernel.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_K = 16


def _cws_kernel(x_ref, r_ref, c_ref, b_ref, istar_ref, tstar_ref, *, block_d):
    """One grid step: data tile [BB, D] x params tile [BK, D] -> [BB, BK]."""
    x = x_ref[...]  # [BB, D]
    r = r_ref[...]  # [BK, D]
    c = c_ref[...]
    b = b_ref[...]

    d = x.shape[-1]
    bb = x.shape[0]
    bk = r.shape[0]

    # Running argmin accumulators. Processing D in chunks of block_d keeps
    # the [BB, BK, block_d] intermediate small enough for VMEM while still
    # vectorizing well.
    best_a = jnp.full((bb, bk), ref.BIG, dtype=jnp.float32)
    best_i = jnp.zeros((bb, bk), dtype=jnp.int32)
    best_t = jnp.zeros((bb, bk), dtype=jnp.float32)

    n_chunks = (d + block_d - 1) // block_d
    for ci in range(n_chunks):
        lo = ci * block_d
        hi = min(lo + block_d, d)
        xs = x[:, None, lo:hi]  # [BB, 1, dc]
        rs = r[None, :, lo:hi]  # [1, BK, dc]
        cs = c[None, :, lo:hi]
        bs = b[None, :, lo:hi]
        pos = xs > 0
        logx = jnp.log(jnp.where(pos, xs, 1.0))
        t = jnp.floor(logx / rs + bs)
        a = cs * jnp.exp(-rs * (t - bs) - rs)
        a = jnp.where(pos, a, ref.BIG)
        # Chunk-local argmin over the last axis.
        idx = jnp.argmin(a, axis=-1)  # [BB, BK]
        amin = jnp.take_along_axis(a, idx[..., None], axis=-1)[..., 0]
        tmin = jnp.take_along_axis(t, idx[..., None], axis=-1)[..., 0]
        gidx = (idx + lo).astype(jnp.int32)
        take = amin < best_a
        best_i = jnp.where(take, gidx, best_i)
        best_t = jnp.where(take, tmin, best_t)
        best_a = jnp.where(take, amin, best_a)

    istar_ref[...] = best_i
    tstar_ref[...] = jnp.clip(best_t, -2.0e9, 2.0e9).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_k", "block_d", "interpret")
)
def cws_hash(
    x,
    r,
    c,
    beta,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_k: int = DEFAULT_BLOCK_K,
    block_d: int = 128,
    interpret: bool = True,
):
    """Batched ICWS hash via the Pallas kernel.

    Args:
      x: ``[B, D]`` float32 nonnegative data batch.
      r, c, beta: ``[K, D]`` float32 parameter matrices.

    Returns:
      (i_star, t_star): each ``[B, K]`` int32.
    """
    bsz, d = x.shape
    k = r.shape[0]
    assert r.shape == (k, d) and c.shape == (k, d) and beta.shape == (k, d)
    bb = min(block_b, bsz)
    bk = min(block_k, k)
    assert bsz % bb == 0, f"batch {bsz} not divisible by block_b {bb}"
    assert k % bk == 0, f"k {k} not divisible by block_k {bk}"
    grid = (bsz // bb, k // bk)
    kernel = functools.partial(_cws_kernel, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bk), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
        ],
        interpret=interpret,
    )(x, r, c, beta)


def vmem_estimate_bytes(block_b: int, block_k: int, block_d: int, d: int) -> int:
    """Static VMEM footprint estimate for one grid step (DESIGN.md §9).

    Input panels: data [BB, D] + 3 param panels [BK, D]; intermediate
    [BB, BK, block_d] triples (t, a, mask-merged); accumulators 3x[BB, BK].
    """
    f32 = 4
    panels = (block_b * d + 3 * block_k * d) * f32
    inter = 2 * block_b * block_k * block_d * f32
    accum = 3 * block_b * block_k * f32
    return panels + inter + accum
