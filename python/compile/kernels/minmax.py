"""Layer-1 Pallas kernel: blocked min-max kernel-matrix tile.

Computes ``K[i, j] = sum_d min(x[i,d], y[j,d]) / sum_d max(x[i,d], y[j,d])``
for a tile of the Gram matrix. Tiling mirrors a matmul epilogue: the
``[BM, D]`` and ``[BN, D]`` panels stream through VMEM, and the reduction
over D happens entirely on-chip (VPU min/max + adds; the MXU stays idle —
see DESIGN.md §Hardware-Adaptation). The *linear* baseline tile
(``linear_matrix``) is a plain dot and does use the MXU on real hardware.

interpret=True only on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 32
DEFAULT_BLOCK_N = 32


def _minmax_kernel(x_ref, y_ref, o_ref, *, block_d):
    x = x_ref[...]  # [BM, D]
    y = y_ref[...]  # [BN, D]
    bm, d = x.shape
    bn = y.shape[0]
    smin = jnp.zeros((bm, bn), dtype=jnp.float32)
    smax = jnp.zeros((bm, bn), dtype=jnp.float32)
    n_chunks = (d + block_d - 1) // block_d
    for ci in range(n_chunks):
        lo = ci * block_d
        hi = min(lo + block_d, d)
        xs = x[:, None, lo:hi]  # [BM, 1, dc]
        ys = y[None, :, lo:hi]  # [1, BN, dc]
        smin = smin + jnp.sum(jnp.minimum(xs, ys), axis=-1)
        smax = smax + jnp.sum(jnp.maximum(xs, ys), axis=-1)
    o_ref[...] = jnp.where(smax > 0, smin / jnp.where(smax > 0, smax, 1.0), 1.0)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_d", "interpret")
)
def minmax_matrix(
    x,
    y,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = 128,
    interpret: bool = True,
):
    """Min-max Gram block between ``x: [M, D]`` and ``y: [N, D]``."""
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, "dimension mismatch"
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0, f"({m},{n}) not divisible by ({bm},{bn})"
    kernel = functools.partial(_minmax_kernel, block_d=block_d)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y)


def _linear_kernel(x_ref, y_ref, o_ref):
    # MXU-targeted tile: a single dot per grid step.
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...].T)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def linear_matrix(
    x,
    y,
    *,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = True,
):
    """Linear Gram block ``x @ y.T`` as a Pallas tile (the baseline)."""
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2
    bm = min(block_m, m)
    bn = min(block_n, n)
    assert m % bm == 0 and n % bn == 0
    return pl.pallas_call(
        _linear_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y)


def vmem_estimate_bytes(block_m: int, block_n: int, block_d: int, d: int) -> int:
    """Static VMEM footprint estimate for one min-max grid step."""
    f32 = 4
    panels = (block_m * d + block_n * d) * f32
    inter = block_m * block_n * block_d * f32
    accum = 2 * block_m * block_n * f32
    return panels + inter + accum
