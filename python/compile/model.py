"""Layer-2 JAX model: the build-time compute graphs the rust coordinator
executes via PJRT.

Three entry points, each jitted and AOT-lowered by :mod:`.aot`:

* :func:`hash_batch` — batched 0-bit CWS hashing (calls the Layer-1
  Pallas kernel :func:`compile.kernels.cws.cws_hash`).
* :func:`minmax_block` — min-max Gram block (Pallas kernel).
* :func:`hash_and_score` — the full serving fwd pass: hash a batch, code
  the samples to ``b_i`` bits, and score against a hashed linear model —
  one fused HLO module so the request path is a single PJRT execute.

Python never runs at serving time: these functions exist to be lowered
once (``make artifacts``) to HLO text.
"""

import jax.numpy as jnp

from .kernels import cws as cws_kernel
from .kernels import minmax as minmax_kernel
from .kernels import ref


def hash_batch(x, r, c, beta):
    """Hash a ``[B, D]`` batch with ``K`` CWS samples -> ``(i*, t*)``.

    The random matrices are runtime inputs (materialized by the rust
    side) so randomness is owned by one place only.
    """
    return cws_kernel.cws_hash(x, r, c, beta)


def minmax_block(x, y):
    """Min-max Gram block between two row batches."""
    return minmax_kernel.minmax_matrix(x, y)


def linear_block(x, y):
    """Linear Gram block (baseline)."""
    return minmax_kernel.linear_matrix(x, y)


def hash_and_score(x, r, c, beta, w):
    """Fused serving path: CWS-hash ``x``, 0-bit-code to ``b_i`` bits
    (``2^b = w.shape[1]``), and score with the hashed linear model.

    Args:
      x: ``[B, D]`` batch; r/c/beta: ``[K, D]`` params.
      w: ``[K, 2^b, C]`` linear-model weights.

    Returns:
      scores ``[B, C]`` float32.
    """
    i_star, _t_star = cws_kernel.cws_hash(x, r, c, beta)
    n_codes = w.shape[1]  # 2^b, static
    codes = jnp.remainder(i_star, n_codes)
    return ref.score_ref(codes, w)
