"""Counter-based CWS parameter derivation — the cross-language contract.

This is the *specification* of how `(r, c, beta)` are derived from
`(seed, sample j, dim i)`. `rust/src/cws/sampler.rs::params_at` implements
the same function; both sides are pinned to shared golden vectors
(`python/tests/test_params.py` and the rust unit tests), so the rust
coordinator can materialize parameter matrices for the AOT executables
and the two backends hash identically.

Recipe (all arithmetic mod 2^64):

    key  = seed XOR mix64((j << 32) | i)
    u_m  = uniform(mix64(key + m * GOLDEN)),  m = 1..5
    r    = -ln(u1 * u2)          # Gamma(2, 1)
    c    = -ln(u3 * u4)          # Gamma(2, 1)
    beta = 1 - u5                # Uniform[0, 1)

where `mix64` is the SplitMix64 finalizer and
`uniform(x) = ((x >> 11) + 1) * 2^-53` (in (0, 1], ln-safe).
"""

import math

import numpy as np

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def mix64(z: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer, vectorized over uint64 arrays."""
    z = np.asarray(z, dtype=np.uint64)
    z = (z ^ (z >> np.uint64(30))) * _M1
    z = (z ^ (z >> np.uint64(27))) * _M2
    return z ^ (z >> np.uint64(31))


def _uniform(x: np.ndarray) -> np.ndarray:
    """(0, 1] uniforms from uint64s (53-bit mantissa)."""
    return ((x >> np.uint64(11)) + np.uint64(1)).astype(np.float64) * (0.5**53)


def params_at(seed: int, j, i):
    """Vectorized `(r, c, beta)` for sample(s) j and dim(s) i.

    Args:
      seed: python int (u64).
      j, i: scalars or broadcastable integer arrays.

    Returns:
      (r, c, beta) float64 arrays of the broadcast shape.
    """
    with np.errstate(over="ignore"):
        j = np.asarray(j, dtype=np.uint64)
        i = np.asarray(i, dtype=np.uint64)
        key = np.uint64(seed) ^ mix64((j << np.uint64(32)) | i)
        us = [
            _uniform(mix64(key + GOLDEN * np.uint64(m)))
            for m in range(1, 6)
        ]
    r = -np.log(us[0] * us[1])
    c = -np.log(us[2] * us[3])
    beta = 1.0 - us[4]
    return r, c, beta


def materialize(seed: int, d: int, k: int):
    """The `[K, D]` float32 parameter matrices the AOT graphs consume —
    identical to `rust materialize_params(seed, d, k)`."""
    jj, ii = np.meshgrid(np.arange(k), np.arange(d), indexing="ij")
    r, c, beta = params_at(seed, jj, ii)
    return (
        r.astype(np.float32),
        c.astype(np.float32),
        beta.astype(np.float32),
    )


# Golden vectors shared with rust/src/cws/sampler.rs (f64, exact).
GOLDEN_VECTORS = [
    # (seed, j, i, r, c, beta)
    (42, 0, 0, 2.1321342897249402, 2.34453352747202, 0.9619698314597537),
    (42, 3, 7, 0.9596960229776987, 1.5230354601677472, 0.4030703586081501),
    (2015, 127, 255, 2.5218182169423575, 2.662209577473352, 0.642316614160663),
    (123456789, 65535, 4095, 0.822830793014408, 1.7835555440010344, 0.3710858790607353),
]


def check_golden() -> None:
    for seed, j, i, er, ec, eb in GOLDEN_VECTORS:
        r, c, b = params_at(seed, j, i)
        assert math.isclose(float(r), er, rel_tol=0, abs_tol=0), (r, er)
        assert math.isclose(float(c), ec, rel_tol=0, abs_tol=0), (c, ec)
        assert math.isclose(float(b), eb, rel_tol=0, abs_tol=0), (b, eb)
