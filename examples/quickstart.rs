//! Quickstart: the library in ~70 lines, via `minmax::prelude`.
//!
//! 1. Compute min-max similarities exactly (Eq. 1) with the `Kernel`
//!    trait.
//! 2. Hash vectors with the kernel's own `Sketcher` linearization and
//!    see the collision fraction estimate the kernel (Eqs. 7–8).
//! 3. Compose the full §4 recipe with the `Pipeline` builder —
//!    scale → sketch → expand → linear SVM — and compare it against the
//!    exact min-max kernel SVM and the linear SVM (the Table-1 effect).
//!
//! Run: `cargo run --release --example quickstart`

use minmax::prelude::*;

fn main() {
    // --- 1. Exact kernel values, via the trait surface.
    let u = [1.0f32, 0.5, 0.0, 2.0, 0.25];
    let v = [0.5f32, 0.5, 1.0, 2.0, 0.25];
    let minmax_kernel = KernelKind::MinMax;
    let kmm = Kernel::eval_dense(&minmax_kernel, &u, &v);
    println!("K_MM(u, v) = {kmm:.4}");

    // --- 2. The kernel's hashed linearization estimates it from
    //        samples alone: any `Sketcher` produces (i*, t*) streams.
    let k = 2048;
    let sketcher = Kernel::sketcher(&minmax_kernel, 2015, k).expect("min-max is linearizable");
    let (su, sv) = (sketcher.sketch_dense(&u), sketcher.sketch_dense(&v));
    let full = collision_fraction(Scheme::FULL, &su, &sv);
    let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
    println!("collision estimates with k={k}:  full-scheme {full:.4}   0-bit {zero:.4}");
    assert!((zero - kmm).abs() < 0.05);

    // --- 3. The composable pipeline on nonlinear data.
    let ds = generate("letter", SynthConfig { seed: 7, n_train: 200, n_test: 300 })
        .expect("generate dataset");
    let cs = c_grid(5);

    // Baselines: exact kernel SVMs (the paper's dashed curves).
    let mm = kernel_svm_sweep(&ds, KernelKind::MinMax, &cs);
    let lin = kernel_svm_sweep(&ds, KernelKind::Linear, &cs);

    // The hashed pipeline: fit/predict in one object.
    let mut pipe = Pipeline::builder()
        .seed(7)
        .samples(512)
        .i_bits(8)
        .scaling(Scaling::None)
        .cost(1.0)
        .build()
        .expect("valid pipeline config");
    pipe.fit(&ds.train_x, &ds.train_y).expect("fit");
    let hashed_acc = pipe.accuracy(&ds.test_x, &ds.test_y).expect("predict");

    println!(
        "letter analog ({} train / {} test): min-max SVM {:.1}%  vs  linear SVM {:.1}%  vs  \
         hashed pipeline (k=512, b=8) {:.1}%",
        ds.n_train(),
        ds.n_test(),
        100.0 * mm.best_accuracy(),
        100.0 * lin.best_accuracy(),
        100.0 * hashed_acc
    );
    assert!(mm.best_accuracy() > lin.best_accuracy());
    assert!(hashed_acc > lin.best_accuracy());
    println!("quickstart OK");
}
