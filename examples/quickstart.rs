//! Quickstart: the library in ~60 lines.
//!
//! 1. Compute min-max similarities exactly (Eq. 1).
//! 2. Hash vectors with 0-bit CWS and see the collision fraction
//!    estimate the kernel (Eqs. 7–8).
//! 3. Train a min-max kernel SVM vs a linear SVM on a small nonlinear
//!    dataset and compare accuracy (the Table-1 effect).
//!
//! Run: `cargo run --release --example quickstart`

use minmax::cws::{collision_fraction, CwsHasher, Scheme};
use minmax::data::synth::{generate, SynthConfig};
use minmax::kernels::{dense_minmax, Kernel};
use minmax::svm::{c_grid, kernel_svm_sweep};

fn main() {
    // --- 1. Exact kernel values.
    let u = [1.0f32, 0.5, 0.0, 2.0, 0.25];
    let v = [0.5f32, 0.5, 1.0, 2.0, 0.25];
    let kmm = dense_minmax(&u, &v);
    println!("K_MM(u, v) = {kmm:.4}");

    // --- 2. 0-bit CWS estimates it from hashes alone.
    let k = 2048;
    let hasher = CwsHasher::new(2015, k);
    let (su, sv) = (hasher.hash_dense(&u), hasher.hash_dense(&v));
    let full = collision_fraction(Scheme::FULL, &su, &sv);
    let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
    println!("collision estimates with k={k}:  full-scheme {full:.4}   0-bit {zero:.4}");
    assert!((zero - kmm).abs() < 0.05);

    // --- 3. Min-max kernel SVM beats linear SVM on nonlinear data.
    let ds = generate("letter", SynthConfig { seed: 7, n_train: 200, n_test: 300 })
        .expect("generate dataset");
    let cs = c_grid(5);
    let mm = kernel_svm_sweep(&ds, Kernel::MinMax, &cs);
    let lin = kernel_svm_sweep(&ds, Kernel::Linear, &cs);
    println!(
        "letter analog ({} train / {} test): min-max SVM {:.1}%  vs  linear SVM {:.1}%",
        ds.n_train(),
        ds.n_test(),
        100.0 * mm.best_accuracy(),
        100.0 * lin.best_accuracy()
    );
    assert!(mm.best_accuracy() > lin.best_accuracy());
    println!("quickstart OK");
}
