//! Near-duplicate retrieval with banded b-bit LSH — the retrieval
//! use-case of the minwise/CWS lineage (syntactic clustering of the
//! web, document dedup; §1's references [4, 5, 13]), scaled up into a
//! recall@k + throughput driver.
//!
//! Builds a corpus of planted near-duplicate groups (jittered copies of
//! group prototypes), indexes it with [`PackedLshIndex`] (banded LSH
//! over b-bit-truncated 0-bit CWS codes in one packed slab), then
//! answers held-out queries and reports, per multi-probe setting:
//!
//! * **recall@k** against exact brute-force min-max top-k,
//! * **queries/s** (scratch reuse — the steady-state serving rate),
//! * **candidates/query** (the sub-linear part: how little of the
//!   corpus each query touches before exact re-ranking).
//!
//! Run: `cargo run --release --example near_duplicates -- [--rows N]
//! [--queries N] [--top K]`. Defaults: 60 000 rows, 200 queries, k=10.

use std::sync::Arc;
use std::time::Instant;

use minmax::cws::{LshConfig, PackedLshIndex, QueryParams, QueryScratch};
use minmax::data::sparse::{Csr, CsrBuilder};
use minmax::kernels::sparse_minmax;
use minmax::util::rng::Pcg64;
use minmax::util::table::{fnum, Table};

const VOCAB: usize = 30_000;
const NNZ: usize = 24;
const GROUP: usize = 8; // near-duplicates per planted group

struct Args {
    rows: usize,
    queries: usize,
    top: usize,
}

fn parse_args() -> Args {
    let mut a = Args { rows: 60_000, queries: 200, top: 10 };
    let mut it = std::env::args().skip(1);
    while let Some(flag) = it.next() {
        let parse = |s: Option<String>| -> usize {
            s.expect("missing value").parse().expect("expected a number")
        };
        match flag.as_str() {
            "--rows" => a.rows = parse(it.next()).max(GROUP),
            "--queries" => a.queries = parse(it.next()).max(1),
            "--top" => a.top = parse(it.next()).max(1),
            other => panic!("unknown flag {other} (use --rows / --queries / --top)"),
        }
    }
    a
}

/// One sparse document: sorted distinct term ids, lognormal weights.
fn prototype(rng: &mut Pcg64) -> Vec<(u32, f32)> {
    let mut ids = rng.sample_indices(VOCAB, NNZ);
    ids.sort_unstable();
    ids.into_iter().map(|i| (i as u32, rng.lognormal(0.0, 1.0) as f32)).collect()
}

/// Near-duplicate of `proto`: jitter every weight, swap ~5% of terms.
/// (`CsrBuilder::push_row` sorts and deduplicates.)
fn jitter(proto: &[(u32, f32)], rng: &mut Pcg64) -> Vec<(u32, f32)> {
    proto
        .iter()
        .map(|&(w, c)| {
            if rng.uniform() < 0.05 {
                (rng.below(VOCAB as u64) as u32, c)
            } else {
                (w, (c as f64 * rng.lognormal(0.0, 0.1)) as f32)
            }
        })
        .collect()
}

fn main() {
    let args = parse_args();
    let mut rng = Pcg64::new(20150704);

    // Corpus: planted groups of near-duplicates. Held-out queries are
    // extra jittered members of random groups — each has ~GROUP genuine
    // near neighbors in the corpus, so recall@k is a real retrieval
    // task, not self-lookup.
    let n_groups = args.rows.div_ceil(GROUP);
    let mut protos: Vec<Vec<(u32, f32)>> = Vec::with_capacity(n_groups);
    let mut b = CsrBuilder::new(VOCAB);
    let mut pushed = 0usize;
    for _ in 0..n_groups {
        let p = prototype(&mut rng);
        for _ in 0..GROUP.min(args.rows - pushed) {
            b.push_row(jitter(&p, &mut rng));
            pushed += 1;
        }
        protos.push(p);
    }
    let corpus = Arc::new(b.finish());
    let n = corpus.rows();
    println!("corpus: {n} documents ({} groups × {GROUP}), vocab {VOCAB}, ~{NNZ} nnz", protos.len());

    let mut qb = CsrBuilder::new(VOCAB);
    for _ in 0..args.queries {
        let g = rng.below(protos.len() as u64) as usize;
        qb.push_row(jitter(&protos[g], &mut rng));
    }
    let queries: Csr = qb.finish();

    // Index: 16 bands × 3 rows = 48 CWS samples/doc, truncated to 8-bit
    // codes — 6 words/row in the packed slab.
    let cfg = LshConfig { bands: 16, rows_per_band: 3, seed: 7 };
    let bits = 8u8;
    let t0 = Instant::now();
    let index = PackedLshIndex::build(Arc::clone(&corpus), cfg, bits).expect("valid config");
    let build_s = t0.elapsed().as_secs_f64();
    println!(
        "indexed in {build_s:.2}s ({:.0} rows/s; k = {}, {} bands × {} rows, {bits}-bit codes; \
         P(candidate | s=0.7) = {:.2}; mean bucket {:.1})",
        n as f64 / build_s,
        cfg.k(),
        cfg.bands,
        cfg.rows_per_band,
        cfg.candidate_probability(0.7),
        index.mean_bucket_size(),
    );

    // Exact brute-force top-k: the ground truth AND the speed baseline.
    let t1 = Instant::now();
    let truth: Vec<Vec<u32>> = queries
        .iter_rows()
        .map(|q| {
            let mut scored: Vec<(u32, f64)> =
                (0..n).map(|i| (i as u32, sparse_minmax(q, corpus.row(i)))).collect();
            scored.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            scored.truncate(args.top);
            scored.into_iter().map(|(id, _)| id).collect()
        })
        .collect();
    let brute_qps = args.queries as f64 / t1.elapsed().as_secs_f64();

    let mut t = Table::new(format!("retrieval: recall@{} + throughput", args.top))
        .header(["mode", "recall", "queries/s", "cands/query", "speedup"]);
    t.row([
        "brute force".to_string(),
        "1.000".to_string(),
        fnum(brute_qps, 0),
        n.to_string(),
        "1.0×".to_string(),
    ]);

    let mut ok = false; // some probe setting reaches recall ≥ 0.9 at ≥ 5×
    let mut s = QueryScratch::new();
    for probes in [0usize, 2, 8] {
        let params = QueryParams { probes, min_agreement: 0.0 };
        let mut cands = 0usize;
        for q in queries.iter_rows() {
            cands += index.candidates_with(q, params, &mut s).len();
        }
        let t2 = Instant::now();
        let mut hit = 0usize;
        for (qi, q) in queries.iter_rows().enumerate() {
            let got = index.query_with(q, args.top, params, &mut s);
            hit += truth[qi].iter().filter(|id| got.iter().any(|&(g, _)| g == **id)).count();
        }
        let qps = args.queries as f64 / t2.elapsed().as_secs_f64();
        let recall = hit as f64 / (args.queries * args.top) as f64;
        let speedup = qps / brute_qps;
        if recall >= 0.9 && speedup >= 5.0 {
            ok = true;
        }
        t.row([
            format!("lsh, {probes} probes"),
            fnum(recall, 3),
            fnum(qps, 0),
            fnum(cands as f64 / args.queries as f64, 1),
            format!("{speedup:.1}×"),
        ]);
    }
    t.print();

    assert!(ok, "no probe setting reached recall@{} ≥ 0.9 at ≥ 5× brute-force speed", args.top);
    println!("near_duplicates OK");
}
