//! Near-duplicate detection with 0-bit-CWS LSH — the retrieval use-case
//! of the minwise/CWS lineage (syntactic clustering of the web, document
//! dedup; §1's references [4, 5, 13]).
//!
//! Builds a corpus of documents with injected near-duplicates (scaled /
//! noised term vectors), indexes it with banding LSH over 0-bit CWS
//! samples, and reports precision/recall of duplicate retrieval plus the
//! candidate-inspection saving vs brute force.
//!
//! Run: `cargo run --release --example near_duplicates`

use minmax::cws::{LshConfig, LshIndex};
use minmax::data::sparse::CsrBuilder;
use minmax::kernels::sparse_minmax;
use minmax::util::rng::Pcg64;
use minmax::util::table::{fnum, Table};

fn main() {
    let mut rng = Pcg64::new(20150704);
    let vocab = 20_000usize;
    let n_base = 400usize;
    let dup_per_doc = 2usize;

    // Corpus: base documents (Zipfian term draws) + near-duplicates
    // (same terms, count jitter + a few term swaps).
    let mut builder = CsrBuilder::new(vocab);
    let mut dup_group: Vec<usize> = Vec::new(); // group id per row
    let mut docs: Vec<Vec<(u32, f32)>> = Vec::new();
    for g in 0..n_base {
        let len = 40 + rng.below(120) as usize;
        let mut counts = std::collections::HashMap::new();
        for _ in 0..len {
            let w = (rng.zipf(vocab as u64, 1.2) - 1) as u32;
            *counts.entry(w).or_insert(0.0f32) += 1.0;
        }
        let base: Vec<(u32, f32)> = counts.into_iter().collect();
        docs.push(base.clone());
        dup_group.push(g);
        for _ in 0..dup_per_doc {
            // Near-duplicate: jitter counts, swap ~5% of terms.
            let dup: Vec<(u32, f32)> = base
                .iter()
                .map(|&(w, c)| {
                    if rng.uniform() < 0.05 {
                        ((rng.zipf(vocab as u64, 1.2) - 1) as u32, c)
                    } else {
                        (w, (c as f64 * rng.lognormal(0.0, 0.15)).max(1.0).round() as f32)
                    }
                })
                .collect();
            docs.push(dup);
            dup_group.push(g);
        }
    }
    // Shuffle rows so groups are not adjacent.
    let mut order: Vec<usize> = (0..docs.len()).collect();
    rng.shuffle(&mut order);
    let group_of: Vec<usize> = order.iter().map(|&i| dup_group[i]).collect();
    for &i in &order {
        builder.push_row(docs[i].clone());
    }
    let corpus = builder.finish();
    let n = corpus.rows();
    println!("corpus: {n} documents ({n_base} groups × {} copies), vocab {vocab}", dup_per_doc + 1);

    // Index.
    let cfg = LshConfig { bands: 32, rows_per_band: 4, seed: 7 };
    let t0 = std::time::Instant::now();
    let index = LshIndex::build(corpus.clone(), cfg);
    println!(
        "indexed in {:.2}s (k = {} samples/doc, {} bands × {} rows; P(candidate | s=0.7) = {:.2})",
        t0.elapsed().as_secs_f64(),
        cfg.k(),
        cfg.bands,
        cfg.rows_per_band,
        cfg.candidate_probability(0.7)
    );

    // Query every document for its near-duplicates.
    let mut tp = 0usize;
    let mut fn_ = 0usize;
    let mut candidates_inspected = 0usize;
    let t1 = std::time::Instant::now();
    for q in 0..n {
        let cands = index.candidates(corpus.row(q));
        candidates_inspected += cands.len();
        let hits: std::collections::HashSet<u32> = cands
            .into_iter()
            .filter(|&id| {
                id as usize != q && sparse_minmax(corpus.row(q), corpus.row(id as usize)) > 0.4
            })
            .collect();
        for other in 0..n {
            if other != q && group_of[other] == group_of[q] {
                if hits.contains(&(other as u32)) {
                    tp += 1;
                } else {
                    fn_ += 1;
                }
            }
        }
    }
    let recall = tp as f64 / (tp + fn_) as f64;
    let brute_force = n * (n - 1);
    let mut t = Table::new("near-duplicate retrieval").header(["metric", "value"]);
    t.row(["duplicate recall".to_string(), fnum(100.0 * recall, 1) + " %"]);
    t.row([
        "pairs inspected vs brute force".to_string(),
        format!("{candidates_inspected} / {brute_force} ({:.1} %)", 100.0 * candidates_inspected as f64 / brute_force as f64),
    ]);
    t.row(["query wall time".to_string(), format!("{:.2}s for {n} queries", t1.elapsed().as_secs_f64())]);
    t.print();
    assert!(recall > 0.9, "recall {recall}");
    assert!(candidates_inspected < brute_force / 10, "LSH must prune >90%");
    println!("near_duplicates OK");
}
