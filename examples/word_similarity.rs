//! Word-similarity estimation (the §3.4 study as a runnable demo).
//!
//! Regenerates three Table-2 word pairs, estimates their min-max
//! similarity with full / 0-bit / 1-bit CWS at increasing k, and prints
//! how the estimates converge to the exact value — the Figures 4–5
//! story on live data.
//!
//! Run: `cargo run --release --example word_similarity`

use minmax::cws::{collision_fraction, CwsHasher, Scheme};
use minmax::data::corpus::{generate_pair, table2_pairs};
use minmax::util::table::{fnum, Table};

fn main() {
    let seed = 2015;
    let pairs = table2_pairs();
    // Small + medium + high-similarity pairs keep the demo quick.
    let chosen = ["GAMBIA", "HONG", "PIPELINE"];
    for g in pairs.iter().filter(|p| chosen.contains(&p.word1)) {
        let gen = generate_pair(g, seed, 0.004);
        println!(
            "\n{}-{}: f1={} f2={}  exact R={:.4}  exact MM={:.4}",
            g.word1,
            g.word2,
            gen.u().nnz(),
            gen.v().nnz(),
            gen.realized_r,
            gen.realized_mm
        );
        let mut t = Table::new("estimates of K_MM")
            .header(["k", "full (i*,t*)", "0-bit (i*)", "1-bit (i*,t* parity)", "|err 0-bit|"]);
        for &k in &[64usize, 256, 1024] {
            let h = CwsHasher::new(seed ^ k as u64, k);
            let su = h.hash_sparse(gen.u());
            let sv = h.hash_sparse(gen.v());
            let full = collision_fraction(Scheme::FULL, &su, &sv);
            let zero = collision_fraction(Scheme::ZERO_BIT, &su, &sv);
            let one = collision_fraction(Scheme::ONE_BIT, &su, &sv);
            t.row([
                k.to_string(),
                fnum(full, 4),
                fnum(zero, 4),
                fnum(one, 4),
                fnum((zero - gen.realized_mm).abs(), 4),
            ]);
        }
        t.print();
    }
    println!("\nword_similarity OK (0-bit tracks the exact min-max kernel)");
}
