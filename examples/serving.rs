//! Serving demo: the full production path.
//!
//! Trains a hashed linear classifier OFFLINE (rust, dual coordinate
//! descent), exports the weights in the `[K, 2^b, C]` layout, and then
//! SERVES batched classification requests through the fused
//! `hash_score` PJRT artifact — raw vector in, class scores out, with
//! Python nowhere on the path. Reports latency percentiles and
//! throughput like a vLLM-style router demo.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example serving`

use std::time::Instant;

use minmax::coordinator::{export_scorer_weights, hash_dataset, PipelineConfig};
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::{Dataset, Matrix};
use minmax::runtime::{default_artifacts_dir, literal_f32, Engine};
use minmax::util::stats::Reservoir;

fn pad_cols(m: &Matrix, d: usize) -> Matrix {
    let dense = m.to_dense();
    let mut out = minmax::data::Dense::zeros(dense.rows(), d);
    for i in 0..dense.rows() {
        out.row_mut(i)[..dense.cols()].copy_from_slice(dense.row(i));
    }
    Matrix::Dense(out)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if !minmax::runtime::pjrt_enabled() {
        eprintln!("built without the `pjrt` feature — rebuild with `--features pjrt`");
        std::process::exit(1);
    }
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }
    let engine = Engine::load_subset(&dir, &["hash_score"])?;
    let spec = engine.spec("hash_score")?.clone();
    let (b, d) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let k = spec.inputs[1].shape[0];
    let codes = spec.inputs[4].shape[1];
    let classes = spec.inputs[4].shape[2];
    println!("artifact hash_score: batch={b} dim={d} k={k} codes={codes} classes={classes}");

    // ---- Offline: train on the youtube analog, export weights.
    let seed = 4242u64;
    let raw = generate("youtube", SynthConfig { seed, n_train: 400, n_test: 1024 })?;
    let ds = Dataset {
        name: raw.name.clone(),
        train_x: pad_cols(&raw.train_x, d),
        train_y: raw.train_y.clone(),
        test_x: pad_cols(&raw.test_x, d),
        test_y: raw.test_y.clone(),
    };
    let pcfg = PipelineConfig { seed, k, i_bits: 8, t_bits: 0 };
    let t0 = Instant::now();
    let hashed = hash_dataset(&ds, &pcfg)?;
    let w = export_scorer_weights(&hashed.train, &ds.train_y, classes, &hashed.expansion, 1.0);
    println!("offline train: {:.2}s ({} train rows)", t0.elapsed().as_secs_f64(), ds.n_train());

    // ---- Online: serve the test set in fixed-size batches via PJRT.
    let (r, c, beta) = minmax::cws::materialize_params(seed, d, k);
    let rl = literal_f32(&r, &[k, d])?;
    let cl = literal_f32(&c, &[k, d])?;
    let bl = literal_f32(&beta, &[k, d])?;
    let wl = literal_f32(&w, &[k, codes, classes])?;

    let test = ds.test_x.to_dense();
    let n = (test.rows() / b) * b;
    let mut lat = Reservoir::new();
    let mut correct = 0usize;
    let serve_start = Instant::now();
    for batch_start in (0..n).step_by(b) {
        let xb = &test.data()[batch_start * d..(batch_start + b) * d];
        let t = Instant::now();
        let outs = engine.run_decoded(
            "hash_score",
            &[literal_f32(xb, &[b, d])?, rl.clone(), cl.clone(), bl.clone(), wl.clone()],
        )?;
        lat.push(t.elapsed().as_secs_f64() * 1e3);
        let scores = outs[0].as_f32().unwrap();
        for i in 0..b {
            let row = &scores[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, bb| a.1.partial_cmp(bb.1).unwrap())
                .unwrap()
                .0 as i32;
            if pred == ds.test_y[batch_start + i] {
                correct += 1;
            }
        }
    }
    let elapsed = serve_start.elapsed().as_secs_f64();
    println!(
        "served {n} requests in {elapsed:.2}s  ({:.0} req/s, batch={b})",
        n as f64 / elapsed
    );
    println!(
        "batch latency: p50={:.2}ms p95={:.2}ms p99={:.2}ms",
        lat.percentile(50.0),
        lat.percentile(95.0),
        lat.percentile(99.0)
    );
    println!("served accuracy: {:.1}%", 100.0 * correct as f64 / n as f64);
    println!("serving OK (PJRT, python-free request path)");
    Ok(())
}
