//! END-TO-END DRIVER (the DESIGN.md §6 validation run).
//!
//! Exercises every layer of the stack on a real small workload and
//! reports the paper's headline metric:
//!
//! 1. generate a synthetic dataset suite (data substrate),
//! 2. compute the exact min-max kernel SVM accuracy and the plain linear
//!    SVM accuracy (the paper's two dashed baselines),
//! 3. stream the dataset through the **coordinator's hashing service**
//!    (PJRT backend when `make artifacts` has run, native otherwise),
//! 4. expand 0-bit CWS features, train the linear SVM on them, and
//!    report hashed-linear accuracy per k — which must climb from the
//!    linear baseline toward the min-max kernel baseline (Figure 7's
//!    story),
//! 5. print service throughput/latency metrics.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use std::time::Duration;

use minmax::coordinator::{HashService, NativeBackend, PjrtBackend, ServiceConfig, SketcherBackend};
use minmax::cws::CwsSample;
use minmax::data::synth::{generate, SynthConfig};
use minmax::data::{Dataset, Matrix};
use minmax::features::Expansion;
use minmax::kernels::KernelKind;
use minmax::svm::{c_grid, kernel_svm_sweep, linear_svm_accuracy};
use minmax::util::table::{fnum, Table};

/// Pad a matrix's columns to `d` (PJRT artifacts have fixed D).
fn pad_cols(m: &Matrix, d: usize) -> Matrix {
    let dense = m.to_dense();
    assert!(dense.cols() <= d);
    let mut out = minmax::data::Dense::zeros(dense.rows(), d);
    for i in 0..dense.rows() {
        out.row_mut(i)[..dense.cols()].copy_from_slice(dense.row(i));
    }
    Matrix::Dense(out)
}

/// Hash every row of a matrix through the online service, preserving
/// order. Exercises submission, batching, backpressure and metrics.
fn hash_via_service(
    svc: &HashService,
    m: &Matrix,
    base_id: u64,
) -> Vec<Option<Vec<CwsSample>>> {
    let dim = m.cols();
    let mut buf = vec![0.0f32; dim];
    let mut out = Vec::with_capacity(m.rows());
    let mut inflight: Vec<(usize, std::sync::mpsc::Receiver<_>)> = Vec::new();
    for i in 0..m.rows() {
        m.row_into(i, &mut buf);
        if !buf.iter().any(|&v| v > 0.0) {
            out.push(None);
            continue;
        }
        out.push(Some(Vec::new()));
        // Retry on backpressure (closed-loop driver).
        loop {
            match svc.submit(base_id + i as u64, buf.clone()) {
                Ok(rx) => {
                    inflight.push((i, rx));
                    break;
                }
                Err(minmax::coordinator::SubmitError::QueueFull) => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                Err(e) => panic!("submit failed: {e}"),
            }
        }
    }
    for (i, rx) in inflight {
        let resp = rx.recv().expect("service response");
        out[i] = Some(resp.samples);
    }
    out
}

fn main() {
    let t0 = std::time::Instant::now();
    let seed = 20150704u64;
    // The artifact `cws_hash` fixes D=256, K=128; choose a dataset where
    // the linear kernel genuinely fails (the letter analog: 16 dims, 26
    // classes, multi-modal — paper: 62.4% linear vs 96.2% min-max) and
    // pad to the artifact dimension.
    let d_artifact = 256;
    let k = 128;
    let ds_raw =
        generate("letter", SynthConfig { seed, n_train: 300, n_test: 400 }).expect("dataset");
    let ds = Dataset {
        name: ds_raw.name.clone(),
        train_x: pad_cols(&ds_raw.train_x, d_artifact),
        train_y: ds_raw.train_y.clone(),
        test_x: pad_cols(&ds_raw.test_x, d_artifact),
        test_y: ds_raw.test_y.clone(),
    };
    println!(
        "dataset: {} ({} train / {} test, dim {} padded to {}, {} classes)",
        ds.name,
        ds.n_train(),
        ds.n_test(),
        ds_raw.dim(),
        d_artifact,
        ds.n_classes()
    );

    // --- Baselines: exact kernel SVMs (the paper's dashed curves).
    let cs = c_grid(5);
    let mm = kernel_svm_sweep(&ds, KernelKind::MinMax, &cs).best_accuracy();
    let lin = kernel_svm_sweep(&ds, KernelKind::Linear, &cs).best_accuracy();
    println!("baselines: min-max kernel SVM {:.1}%   linear SVM {:.1}%", 100.0 * mm, 100.0 * lin);

    // --- The coordinator service (PJRT if artifacts exist).
    let artifacts = minmax::runtime::default_artifacts_dir();
    let use_pjrt = minmax::runtime::pjrt_enabled() && artifacts.join("manifest.json").exists();
    let backend: Box<dyn SketcherBackend> = if use_pjrt {
        println!("backend: PJRT (artifact cws_hash)");
        Box::new(PjrtBackend::new(artifacts, "cws_hash"))
    } else {
        println!("backend: native (build with --features pjrt and run `make artifacts` for the PJRT path)");
        Box::new(NativeBackend)
    };
    let svc = HashService::start(
        ServiceConfig {
            seed,
            k,
            dim: d_artifact,
            max_batch: 64,
            max_wait: Duration::from_millis(1),
            queue_cap: 512,
        },
        backend,
    )
    .expect("start hashing service");

    let train_samples = hash_via_service(&svc, &ds.train_x, 0);
    let test_samples = hash_via_service(&svc, &ds.test_x, 1_000_000);
    let snap = svc.metrics().snapshot();
    println!("service: {}", snap.render());

    // --- Hashed linear SVM accuracy per k (prefixes of the k=128 hash).
    let mut table = Table::new("hashed 0-bit CWS + linear SVM (b_i = 8)")
        .header(["k", "accuracy %", "gap to min-max kernel (pp)"]);
    let prefix = |samples: &[Option<Vec<CwsSample>>], kk: usize| -> Vec<Option<Vec<CwsSample>>> {
        samples.iter().map(|o| o.as_ref().map(|s| s[..kk].to_vec())).collect()
    };
    let mut last_acc = 0.0;
    for &kk in &[16usize, 32, 64, 128] {
        let e = Expansion::new(kk, 8);
        let ftr = e.expand(&prefix(&train_samples, kk));
        let fte = e.expand(&prefix(&test_samples, kk));
        let acc = cs
            .iter()
            .map(|&c| linear_svm_accuracy(&ftr, &ds.train_y, &fte, &ds.test_y, ds.n_classes(), c))
            .fold(f64::NEG_INFINITY, f64::max);
        table.row([
            kk.to_string(),
            fnum(100.0 * acc, 1),
            fnum(100.0 * (mm - acc), 1),
        ]);
        last_acc = acc;
    }
    table.print();

    // --- Headline claim check: hashed accuracy recovers most of the
    // kernel-over-linear gap at k = 128.
    let recovered = (last_acc - lin) / (mm - lin).max(1e-9);
    println!(
        "headline: hashed-linear recovers {:.0}% of the (min-max − linear) gap at k={k}",
        100.0 * recovered
    );
    println!("total wall time {:.1}s", t0.elapsed().as_secs_f64());
    svc.shutdown();
    if recovered < 0.5 {
        eprintln!("WARNING: expected ≥50% gap recovery");
        std::process::exit(1);
    }
    println!("end_to_end OK");
}
